//! Binary GraphDef codec: the master ships placed partitions to workers
//! (§3.3 distributed execution), so graphs must round-trip over the wire.
//! Little-endian, length-prefixed strings, tensor payloads via
//! `tensor::codec`.

use super::{AttrValue, Endpoint, Graph, Node, NodeId};
use crate::error::{Result, Status};
use crate::tensor::{codec, DType, Shape};
use crate::util::byteorder::LittleEndian;

pub fn encode_graph(g: &Graph) -> Vec<u8> {
    let mut out = Vec::new();
    put_u32(&mut out, g.len() as u32);
    for n in &g.nodes {
        put_str(&mut out, &n.name);
        put_str(&mut out, &n.op);
        put_u32(&mut out, n.inputs.len() as u32);
        for e in &n.inputs {
            put_u32(&mut out, e.node.0 as u32);
            put_u32(&mut out, e.port as u32);
        }
        put_u32(&mut out, n.control_inputs.len() as u32);
        for c in &n.control_inputs {
            put_u32(&mut out, c.0 as u32);
        }
        put_str(&mut out, &n.requested_device);
        put_str(&mut out, n.assigned_device.as_deref().unwrap_or(""));
        put_u32(&mut out, n.attrs.len() as u32);
        for (k, v) in &n.attrs {
            put_str(&mut out, k);
            encode_attr(&mut out, v);
        }
    }
    out
}

pub fn decode_graph(buf: &[u8]) -> Result<Graph> {
    let mut pos = 0usize;
    let n = get_u32(buf, &mut pos)? as usize;
    let mut g = Graph::new();
    // Two-pass construction is unnecessary: ids are indices and encode
    // preserves order; but forward references (loop back-edges) mean we
    // must bypass `add` validation and build directly.
    let mut nodes = Vec::with_capacity(n);
    for _ in 0..n {
        let name = get_str(buf, &mut pos)?;
        let op = get_str(buf, &mut pos)?;
        let ni = get_u32(buf, &mut pos)? as usize;
        let mut inputs = Vec::with_capacity(ni);
        for _ in 0..ni {
            let node = get_u32(buf, &mut pos)? as usize;
            let port = get_u32(buf, &mut pos)? as usize;
            inputs.push(Endpoint::new(NodeId(node), port));
        }
        let nc = get_u32(buf, &mut pos)? as usize;
        let mut control_inputs = Vec::with_capacity(nc);
        for _ in 0..nc {
            control_inputs.push(NodeId(get_u32(buf, &mut pos)? as usize));
        }
        let requested_device = get_str(buf, &mut pos)?;
        let assigned = get_str(buf, &mut pos)?;
        let na = get_u32(buf, &mut pos)? as usize;
        let mut attrs = std::collections::BTreeMap::new();
        for _ in 0..na {
            let k = get_str(buf, &mut pos)?;
            let v = decode_attr(buf, &mut pos)?;
            attrs.insert(k, v);
        }
        nodes.push(Node {
            name,
            op,
            inputs,
            control_inputs,
            attrs,
            requested_device,
            assigned_device: if assigned.is_empty() { None } else { Some(assigned) },
        });
    }
    for node in nodes {
        g.add_unchecked(node);
    }
    Ok(g)
}

/// Magic prefix of a GraphDef *file* (the in-memory codec above is
/// headerless — the distributed runtime frames it itself).
const GRAPHDEF_MAGIC: &[u8; 8] = b"RFLOWGDF";

/// Write a graph to `path` as a GraphDef file: magic + [`encode_graph`]
/// bytes, via `util::fsutil::atomic_write` (unique temp file + rename)
/// so a crash mid-write never corrupts a model artifact the serving
/// layer may be about to load.
pub fn write_graphdef(path: &std::path::Path, g: &Graph) -> Result<()> {
    let body = encode_graph(g);
    let mut buf = Vec::with_capacity(body.len() + GRAPHDEF_MAGIC.len());
    buf.extend_from_slice(GRAPHDEF_MAGIC);
    buf.extend_from_slice(&body);
    crate::util::fsutil::atomic_write(path, &buf)
}

/// Read a GraphDef file written by [`write_graphdef`].
pub fn read_graphdef(path: &std::path::Path) -> Result<Graph> {
    let mut buf = Vec::new();
    use std::io::Read as _;
    std::fs::File::open(path)
        .map_err(|e| Status::not_found(format!("graphdef {path:?}: {e}")))?
        .read_to_end(&mut buf)?;
    if buf.len() < GRAPHDEF_MAGIC.len() || &buf[..GRAPHDEF_MAGIC.len()] != GRAPHDEF_MAGIC {
        return Err(Status::invalid_argument(format!("{path:?} is not a rustflow GraphDef")));
    }
    decode_graph(&buf[GRAPHDEF_MAGIC.len()..])
}

fn encode_attr(out: &mut Vec<u8>, v: &AttrValue) {
    match v {
        AttrValue::I64(x) => {
            out.push(0);
            put_i64(out, *x);
        }
        AttrValue::F32(x) => {
            out.push(1);
            let mut b = [0u8; 4];
            LittleEndian::write_f32(&mut b, *x);
            out.extend_from_slice(&b);
        }
        AttrValue::Bool(x) => {
            out.push(2);
            out.push(*x as u8);
        }
        AttrValue::Str(s) => {
            out.push(3);
            put_str(out, s);
        }
        AttrValue::Type(d) => {
            out.push(4);
            out.push(d.as_u8());
        }
        AttrValue::Shape(s) => {
            out.push(5);
            put_u32(out, s.rank() as u32);
            for &d in s.dims() {
                put_i64(out, d as i64);
            }
        }
        AttrValue::Tensor(t) => {
            out.push(6);
            let payload = codec::encode(t);
            put_u32(out, payload.len() as u32);
            out.extend_from_slice(&payload);
        }
        AttrValue::ListI64(xs) => {
            out.push(7);
            put_u32(out, xs.len() as u32);
            for &x in xs {
                put_i64(out, x);
            }
        }
        AttrValue::ListStr(xs) => {
            out.push(8);
            put_u32(out, xs.len() as u32);
            for x in xs {
                put_str(out, x);
            }
        }
        AttrValue::ListType(xs) => {
            out.push(9);
            put_u32(out, xs.len() as u32);
            for x in xs {
                out.push(x.as_u8());
            }
        }
        AttrValue::ListShape(xs) => {
            out.push(10);
            put_u32(out, xs.len() as u32);
            for s in xs {
                put_u32(out, s.rank() as u32);
                for &d in s.dims() {
                    put_i64(out, d as i64);
                }
            }
        }
    }
}

fn decode_attr(buf: &[u8], pos: &mut usize) -> Result<AttrValue> {
    let tag = get_u8(buf, pos)?;
    Ok(match tag {
        0 => AttrValue::I64(get_i64(buf, pos)?),
        1 => {
            need(buf, *pos, 4)?;
            let v = LittleEndian::read_f32(&buf[*pos..]);
            *pos += 4;
            AttrValue::F32(v)
        }
        2 => AttrValue::Bool(get_u8(buf, pos)? != 0),
        3 => AttrValue::Str(get_str(buf, pos)?),
        4 => AttrValue::Type(DType::from_u8(get_u8(buf, pos)?)?),
        5 => {
            let rank = get_u32(buf, pos)? as usize;
            let mut dims = Vec::with_capacity(rank);
            for _ in 0..rank {
                dims.push(get_i64(buf, pos)? as usize);
            }
            AttrValue::Shape(Shape(dims))
        }
        6 => {
            let len = get_u32(buf, pos)? as usize;
            need(buf, *pos, len)?;
            let (t, used) = codec::decode(&buf[*pos..*pos + len])?;
            if used != len {
                return Err(Status::invalid_argument("attr tensor length mismatch"));
            }
            *pos += len;
            AttrValue::Tensor(t)
        }
        7 => {
            let n = get_u32(buf, pos)? as usize;
            let mut xs = Vec::with_capacity(n);
            for _ in 0..n {
                xs.push(get_i64(buf, pos)?);
            }
            AttrValue::ListI64(xs)
        }
        8 => {
            let n = get_u32(buf, pos)? as usize;
            let mut xs = Vec::with_capacity(n);
            for _ in 0..n {
                xs.push(get_str(buf, pos)?);
            }
            AttrValue::ListStr(xs)
        }
        9 => {
            let n = get_u32(buf, pos)? as usize;
            let mut xs = Vec::with_capacity(n);
            for _ in 0..n {
                xs.push(DType::from_u8(get_u8(buf, pos)?)?);
            }
            AttrValue::ListType(xs)
        }
        10 => {
            let n = get_u32(buf, pos)? as usize;
            let mut xs = Vec::with_capacity(n);
            for _ in 0..n {
                let rank = get_u32(buf, pos)? as usize;
                let mut dims = Vec::with_capacity(rank);
                for _ in 0..rank {
                    dims.push(get_i64(buf, pos)? as usize);
                }
                xs.push(Shape(dims));
            }
            AttrValue::ListShape(xs)
        }
        other => return Err(Status::invalid_argument(format!("unknown attr tag {other}"))),
    })
}

// ---- primitives -----------------------------------------------------------

fn need(buf: &[u8], pos: usize, n: usize) -> Result<()> {
    if buf.len() < pos + n {
        return Err(Status::invalid_argument("truncated graph encoding"));
    }
    Ok(())
}

fn put_u32(out: &mut Vec<u8>, v: u32) {
    let mut b = [0u8; 4];
    LittleEndian::write_u32(&mut b, v);
    out.extend_from_slice(&b);
}

fn put_i64(out: &mut Vec<u8>, v: i64) {
    let mut b = [0u8; 8];
    LittleEndian::write_i64(&mut b, v);
    out.extend_from_slice(&b);
}

fn put_str(out: &mut Vec<u8>, s: &str) {
    put_u32(out, s.len() as u32);
    out.extend_from_slice(s.as_bytes());
}

fn get_u8(buf: &[u8], pos: &mut usize) -> Result<u8> {
    need(buf, *pos, 1)?;
    let v = buf[*pos];
    *pos += 1;
    Ok(v)
}

fn get_u32(buf: &[u8], pos: &mut usize) -> Result<u32> {
    need(buf, *pos, 4)?;
    let v = LittleEndian::read_u32(&buf[*pos..]);
    *pos += 4;
    Ok(v)
}

fn get_i64(buf: &[u8], pos: &mut usize) -> Result<i64> {
    need(buf, *pos, 8)?;
    let v = LittleEndian::read_i64(&buf[*pos..]);
    *pos += 8;
    Ok(v)
}

fn get_str(buf: &[u8], pos: &mut usize) -> Result<String> {
    let len = get_u32(buf, pos)? as usize;
    need(buf, *pos, len)?;
    let s = std::str::from_utf8(&buf[*pos..*pos + len])
        .map_err(|_| Status::invalid_argument("invalid utf8 string"))?
        .to_string();
    *pos += len;
    Ok(s)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ops::builder::GraphBuilder;
    use crate::tensor::Tensor;

    #[test]
    fn roundtrip_simple() {
        let mut b = GraphBuilder::new();
        let x = b.scalar(2.0);
        let y = b.with_device("/device:cpu:1", |b| b.neg(x));
        b.graph.node_mut(y.node).assigned_device = Some("/job:w/task:0/device:cpu:1".into());
        let enc = encode_graph(&b.graph);
        let dec = decode_graph(&enc).unwrap();
        assert_eq!(dec.len(), b.graph.len());
        let yn = dec.find("Neg").unwrap();
        assert_eq!(dec.node(yn).requested_device, "/device:cpu:1");
        assert_eq!(dec.node(yn).assigned_device.as_deref(), Some("/job:w/task:0/device:cpu:1"));
        assert_eq!(dec.node(yn).inputs[0].node, x.node);
    }

    #[test]
    fn graphdef_file_roundtrip() {
        let dir = std::env::temp_dir().join(format!("rustflow-gdf-{}", std::process::id()));
        let _ = std::fs::create_dir_all(&dir);
        let path = dir.join("model.graphdef");
        let mut b = GraphBuilder::new();
        let x = b.scalar(3.0);
        b.neg(x);
        write_graphdef(&path, &b.graph).unwrap();
        let dec = read_graphdef(&path).unwrap();
        assert_eq!(dec.len(), b.graph.len());
        assert!(dec.find("Neg").is_some());
        // No stray tmp file, and garbage is rejected with a clear error.
        assert!(!path.with_extension("tmp").exists());
        let bad = dir.join("garbage.graphdef");
        std::fs::write(&bad, b"not a graphdef").unwrap();
        assert!(read_graphdef(&bad).is_err());
        assert_eq!(
            read_graphdef(&dir.join("missing.graphdef")).unwrap_err().code,
            crate::error::Code::NotFound
        );
    }

    #[test]
    fn roundtrip_all_attr_kinds() {
        let mut b = GraphBuilder::new();
        let id = b
            .op(
                "NoOp",
                "attrs",
                vec![],
                vec![
                    ("i", AttrValue::I64(-5)),
                    ("f", AttrValue::F32(1.5)),
                    ("b", AttrValue::Bool(true)),
                    ("s", AttrValue::Str("hello".into())),
                    ("t", AttrValue::Type(DType::I64)),
                    ("sh", AttrValue::Shape(Shape(vec![2, 3]))),
                    ("tv", AttrValue::Tensor(Tensor::from_f32(vec![2], vec![1., 2.]).unwrap())),
                    ("li", AttrValue::ListI64(vec![1, 2, 3])),
                    ("ls", AttrValue::ListStr(vec!["a".into(), "b".into()])),
                    ("lt", AttrValue::ListType(vec![DType::F32, DType::Bool])),
                    ("lsh", AttrValue::ListShape(vec![Shape(vec![1]), Shape(vec![])])),
                ],
            )
            .unwrap();
        let enc = encode_graph(&b.graph);
        let dec = decode_graph(&enc).unwrap();
        let n = dec.node(id);
        assert_eq!(n.attrs.len(), 11);
        assert_eq!(n.attrs["i"].as_i64().unwrap(), -5);
        assert_eq!(n.attrs["tv"].as_tensor().unwrap().as_f32().unwrap(), &[1., 2.]);
        assert_eq!(n.attrs["lsh"].as_list_shape().unwrap().len(), 2);
    }

    #[test]
    fn roundtrip_loop_graph() {
        let mut b = GraphBuilder::new();
        let zero = b.scalar(0.0);
        b.while_loop(
            "f",
            vec![zero],
            |b, v| {
                let lim = b.scalar(3.0);
                Ok(b.less(v[0], lim))
            },
            |b, v| {
                let one = b.scalar(1.0);
                Ok(vec![b.add(v[0], one)])
            },
        )
        .unwrap();
        let enc = encode_graph(&b.graph);
        let dec = decode_graph(&enc).unwrap();
        assert_eq!(dec.len(), b.graph.len());
        assert!(dec.topo_order().is_ok());
    }

    #[test]
    fn roundtrip_fused_elementwise_attrs() {
        // Regression: the optimizer's FusedElementwise nodes must survive
        // the wire (masters ship optimized partitions to workers). The
        // `ops` step list and `T` must round-trip exactly, and the decoded
        // steps must parse back to the same program.
        let mut b = GraphBuilder::new();
        let x = b.placeholder("x", DType::F32).unwrap();
        let c = b.scalar(2.0);
        let id = b
            .op(
                "FusedElementwise",
                "fused",
                vec![x, c],
                vec![
                    (
                        "ops",
                        AttrValue::ListStr(vec!["Mul,r,1".into(), "Neg".into(), "Tanh".into()]),
                    ),
                    ("T", AttrValue::Type(DType::F32)),
                ],
            )
            .unwrap();
        let enc = encode_graph(&b.graph);
        let dec = decode_graph(&enc).unwrap();
        let n = dec.node(id);
        assert_eq!(n.op, "FusedElementwise");
        assert_eq!(n.attrs["ops"], b.graph.node(id).attrs["ops"]);
        assert_eq!(n.attrs["T"].as_type().unwrap(), DType::F32);
        assert_eq!(n.inputs.len(), 2);
        let steps = crate::kernels::fused::parse_steps(&n.attrs["ops"]).unwrap();
        assert_eq!(steps.len(), 3);
        assert_eq!(steps[0].arg, Some(1));
    }

    #[test]
    fn garbage_rejected() {
        assert!(decode_graph(&[1, 2, 3]).is_err());
        let mut b = GraphBuilder::new();
        b.scalar(1.0);
        let enc = encode_graph(&b.graph);
        assert!(decode_graph(&enc[..enc.len() - 2]).is_err());
    }
}
