//! The dataflow graph (§2): nodes instantiate operations; tensors flow on
//! normal edges; control dependencies are edges that carry no data but
//! impose happens-before.

pub mod attr;
pub mod serde;

pub use attr::AttrValue;

use crate::error::{Result, Status};
use std::collections::{BTreeMap, HashMap, HashSet, VecDeque};

/// Index of a node within its graph.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub struct NodeId(pub usize);

/// A tensor-producing endpoint: node output `port` (the paper's
/// `"bar:0"` notation, §4.2).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub struct Endpoint {
    pub node: NodeId,
    pub port: usize,
}

impl Endpoint {
    pub fn new(node: NodeId, port: usize) -> Endpoint {
        Endpoint { node, port }
    }
}

impl From<NodeId> for Endpoint {
    fn from(node: NodeId) -> Endpoint {
        Endpoint { node, port: 0 }
    }
}

/// One node of the dataflow graph.
#[derive(Debug, Clone)]
pub struct Node {
    pub name: String,
    pub op: String,
    /// Data inputs, in signature order.
    pub inputs: Vec<Endpoint>,
    /// Control dependencies: these nodes must finish before this one starts.
    pub control_inputs: Vec<NodeId>,
    pub attrs: BTreeMap<String, AttrValue>,
    /// User-requested (possibly partial) device constraint, e.g.
    /// "/job:worker/task:17" or "/device:cpu:1" (§4.3). Empty = any.
    pub requested_device: String,
    /// Placer-assigned full device name (§3.2.1).
    pub assigned_device: Option<String>,
}

impl Node {
    pub fn attr(&self, name: &str) -> Result<&AttrValue> {
        self.attrs
            .get(name)
            .ok_or_else(|| Status::invalid_argument(format!("node {}: missing attr {name}", self.name)))
    }

    pub fn attr_opt(&self, name: &str) -> Option<&AttrValue> {
        self.attrs.get(name)
    }

    /// The conventional dtype attr `T`, defaulting to f32 when absent.
    pub fn dtype_attr(&self) -> crate::tensor::DType {
        self.attrs
            .get("T")
            .and_then(|a| a.as_type().ok())
            .unwrap_or(crate::tensor::DType::F32)
    }
}

/// A parsed "name:port" reference used by feeds/fetches (§4.2).
#[derive(Debug, Clone, PartialEq, Eq, Hash)]
pub struct TensorName {
    pub node: String,
    pub port: usize,
}

impl TensorName {
    /// Parse `"node"` (port 0) or `"node:2"`.
    pub fn parse(s: &str) -> Result<TensorName> {
        match s.rsplit_once(':') {
            Some((node, port)) if !node.is_empty() => {
                let port: usize = port
                    .parse()
                    .map_err(|_| Status::invalid_argument(format!("bad tensor name {s:?}")))?;
                Ok(TensorName { node: node.to_string(), port })
            }
            _ => Ok(TensorName { node: s.to_string(), port: 0 }),
        }
    }
}

impl std::fmt::Display for TensorName {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "{}:{}", self.node, self.port)
    }
}

/// The dataflow graph. Nodes are append-only; rewrites build new graphs
/// (pruning, partitioning) or redirect edges in place (CSE).
#[derive(Debug, Clone, Default)]
pub struct Graph {
    pub nodes: Vec<Node>,
    by_name: HashMap<String, NodeId>,
}

impl Graph {
    pub fn new() -> Graph {
        Graph::default()
    }

    pub fn len(&self) -> usize {
        self.nodes.len()
    }

    pub fn is_empty(&self) -> bool {
        self.nodes.is_empty()
    }

    pub fn node(&self, id: NodeId) -> &Node {
        &self.nodes[id.0]
    }

    pub fn node_mut(&mut self, id: NodeId) -> &mut Node {
        &mut self.nodes[id.0]
    }

    pub fn find(&self, name: &str) -> Option<NodeId> {
        self.by_name.get(name).copied()
    }

    pub fn must_find(&self, name: &str) -> Result<NodeId> {
        self.find(name)
            .ok_or_else(|| Status::not_found(format!("node {name:?} not in graph")))
    }

    pub fn ids(&self) -> impl Iterator<Item = NodeId> {
        (0..self.nodes.len()).map(NodeId)
    }

    /// Append a node. Name must be unique.
    pub fn add(&mut self, node: Node) -> Result<NodeId> {
        if node.name.is_empty() {
            return Err(Status::invalid_argument("node name must be non-empty"));
        }
        if self.by_name.contains_key(&node.name) {
            return Err(Status::already_exists(format!("duplicate node name {:?}", node.name)));
        }
        for e in &node.inputs {
            if e.node.0 >= self.nodes.len() {
                return Err(Status::invalid_argument(format!(
                    "node {:?} references out-of-range input node {}",
                    node.name, e.node.0
                )));
            }
        }
        for c in &node.control_inputs {
            if c.0 >= self.nodes.len() {
                return Err(Status::invalid_argument(format!(
                    "node {:?} references out-of-range control input {}",
                    node.name, c.0
                )));
            }
        }
        let id = NodeId(self.nodes.len());
        self.by_name.insert(node.name.clone(), id);
        self.nodes.push(node);
        Ok(id)
    }

    /// Append without edge-range validation (wire decoding, where loop
    /// back-edges reference not-yet-decoded nodes).
    pub(crate) fn add_unchecked(&mut self, node: Node) -> NodeId {
        let id = NodeId(self.nodes.len());
        self.by_name.insert(node.name.clone(), id);
        self.nodes.push(node);
        id
    }

    /// Generate a fresh name with the given prefix.
    pub fn unique_name(&self, prefix: &str) -> String {
        if !self.by_name.contains_key(prefix) {
            return prefix.to_string();
        }
        let mut i = 1;
        loop {
            let candidate = format!("{prefix}_{i}");
            if !self.by_name.contains_key(&candidate) {
                return candidate;
            }
            i += 1;
        }
    }

    /// Out-edges: for each node, which (consumer, input-slot) pairs read
    /// each output, plus control consumers.
    pub fn fanout(&self) -> Fanout {
        let mut data: Vec<Vec<(NodeId, usize)>> = vec![Vec::new(); self.nodes.len()];
        let mut control: Vec<Vec<NodeId>> = vec![Vec::new(); self.nodes.len()];
        for (i, n) in self.nodes.iter().enumerate() {
            for (slot, e) in n.inputs.iter().enumerate() {
                data[e.node.0].push((NodeId(i), slot));
            }
            for c in &n.control_inputs {
                control[c.0].push(NodeId(i));
            }
        }
        Fanout { data, control }
    }

    /// Reverse-reachability from `targets` over data + control edges — the
    /// transitive closure Run() must execute (§2 "Sessions").
    pub fn reachable_from(&self, targets: &[NodeId]) -> HashSet<NodeId> {
        let mut seen: HashSet<NodeId> = HashSet::new();
        let mut queue: VecDeque<NodeId> = VecDeque::new();
        for &t in targets {
            if seen.insert(t) {
                queue.push_back(t);
            }
        }
        while let Some(id) = queue.pop_front() {
            let n = &self.nodes[id.0];
            for e in &n.inputs {
                if seen.insert(e.node) {
                    queue.push_back(e.node);
                }
            }
            for &c in &n.control_inputs {
                if seen.insert(c) {
                    queue.push_back(c);
                }
            }
        }
        seen
    }

    /// Topological order (Kahn). Fails on cycles *unless* the cycle passes
    /// through a `NextIteration` back-edge, which the §4.4 executor handles
    /// with frame tags — those edges are skipped here, matching TF's
    /// treatment of cyclic control-flow graphs as static.
    pub fn topo_order(&self) -> Result<Vec<NodeId>> {
        let n = self.nodes.len();
        let mut indegree = vec![0usize; n];
        let mut preds: Vec<Vec<NodeId>> = vec![Vec::new(); n];
        for (i, node) in self.nodes.iter().enumerate() {
            for e in &node.inputs {
                // Merge's input from NextIteration is the loop back-edge.
                if self.nodes[e.node.0].op == "NextIteration" {
                    continue;
                }
                indegree[i] += 1;
                preds[i].push(e.node);
            }
            for c in &node.control_inputs {
                if self.nodes[c.0].op == "NextIteration" {
                    continue;
                }
                indegree[i] += 1;
                preds[i].push(*c);
            }
        }
        let fanout = {
            let mut f: Vec<Vec<NodeId>> = vec![Vec::new(); n];
            for (i, ps) in preds.iter().enumerate() {
                for p in ps {
                    f[p.0].push(NodeId(i));
                }
            }
            f
        };
        let mut queue: VecDeque<NodeId> =
            (0..n).filter(|&i| indegree[i] == 0).map(NodeId).collect();
        let mut order = Vec::with_capacity(n);
        while let Some(id) = queue.pop_front() {
            order.push(id);
            for &succ in &fanout[id.0] {
                indegree[succ.0] -= 1;
                if indegree[succ.0] == 0 {
                    queue.push_back(succ);
                }
            }
        }
        if order.len() != n {
            return Err(Status::invalid_argument(
                "graph contains a cycle not mediated by NextIteration",
            ));
        }
        Ok(order)
    }

    /// Build a subgraph containing exactly `keep`, preserving relative
    /// order. Returns the new graph and old→new id map.
    pub fn subgraph(&self, keep: &HashSet<NodeId>) -> (Graph, HashMap<NodeId, NodeId>) {
        // Two passes: ids first (edges may point forward, e.g. loop
        // back-edges or feed rewrites), then nodes.
        let mut remap: HashMap<NodeId, NodeId> = HashMap::new();
        let mut next = 0usize;
        for id in self.ids() {
            if keep.contains(&id) {
                remap.insert(id, NodeId(next));
                next += 1;
            }
        }
        let mut g = Graph::new();
        for id in self.ids() {
            if !keep.contains(&id) {
                continue;
            }
            let old = &self.nodes[id.0];
            let node = Node {
                name: old.name.clone(),
                op: old.op.clone(),
                inputs: old
                    .inputs
                    .iter()
                    .map(|e| Endpoint::new(remap[&e.node], e.port))
                    .collect(),
                control_inputs: old
                    .control_inputs
                    .iter()
                    .filter_map(|c| remap.get(c).copied())
                    .collect(),
                attrs: old.attrs.clone(),
                requested_device: old.requested_device.clone(),
                assigned_device: old.assigned_device.clone(),
            };
            let new_id = NodeId(g.nodes.len());
            g.by_name.insert(node.name.clone(), new_id);
            g.nodes.push(node);
        }
        (g, remap)
    }

    /// Human-readable dump (used by `rustflow exp fig2` etc.).
    pub fn dump(&self) -> String {
        let mut out = String::new();
        for (i, n) in self.nodes.iter().enumerate() {
            let inputs: Vec<String> = n
                .inputs
                .iter()
                .map(|e| {
                    let name = &self.nodes[e.node.0].name;
                    if e.port == 0 {
                        name.clone()
                    } else {
                        format!("{name}:{}", e.port)
                    }
                })
                .chain(n.control_inputs.iter().map(|c| format!("^{}", self.nodes[c.0].name)))
                .collect();
            let dev = n
                .assigned_device
                .as_deref()
                .or(if n.requested_device.is_empty() { None } else { Some(&n.requested_device) })
                .map(|d| format!(" @{d}"))
                .unwrap_or_default();
            out.push_str(&format!("#{i} {} = {}({}){dev}\n", n.name, n.op, inputs.join(", ")));
        }
        out
    }
}

/// Precomputed out-edge lists.
pub struct Fanout {
    /// data[src] = (consumer node, consumer input slot)
    pub data: Vec<Vec<(NodeId, usize)>>,
    /// control[src] = consumer nodes
    pub control: Vec<Vec<NodeId>>,
}

#[cfg(test)]
mod tests {
    use super::*;

    fn simple_node(name: &str, op: &str, inputs: Vec<Endpoint>) -> Node {
        Node {
            name: name.into(),
            op: op.into(),
            inputs,
            control_inputs: vec![],
            attrs: BTreeMap::new(),
            requested_device: String::new(),
            assigned_device: None,
        }
    }

    fn diamond() -> (Graph, NodeId, NodeId, NodeId, NodeId) {
        // a -> b, a -> c, (b,c) -> d
        let mut g = Graph::new();
        let a = g.add(simple_node("a", "Const", vec![])).unwrap();
        let b = g.add(simple_node("b", "Neg", vec![a.into()])).unwrap();
        let c = g.add(simple_node("c", "Neg", vec![a.into()])).unwrap();
        let d = g
            .add(simple_node("d", "Add", vec![b.into(), c.into()]))
            .unwrap();
        (g, a, b, c, d)
    }

    #[test]
    fn add_and_lookup() {
        let (g, a, ..) = diamond();
        assert_eq!(g.len(), 4);
        assert_eq!(g.find("a"), Some(a));
        assert_eq!(g.find("zz"), None);
        assert!(g.must_find("zz").is_err());
    }

    #[test]
    fn duplicate_names_rejected() {
        let mut g = Graph::new();
        g.add(simple_node("x", "Const", vec![])).unwrap();
        assert!(g.add(simple_node("x", "Const", vec![])).is_err());
    }

    #[test]
    fn forward_references_rejected() {
        let mut g = Graph::new();
        let bad = simple_node("y", "Neg", vec![Endpoint::new(NodeId(5), 0)]);
        assert!(g.add(bad).is_err());
    }

    #[test]
    fn unique_names() {
        let mut g = Graph::new();
        g.add(simple_node("x", "Const", vec![])).unwrap();
        assert_eq!(g.unique_name("x"), "x_1");
        assert_eq!(g.unique_name("y"), "y");
    }

    #[test]
    fn tensor_name_parse() {
        assert_eq!(
            TensorName::parse("bar:1").unwrap(),
            TensorName { node: "bar".into(), port: 1 }
        );
        assert_eq!(
            TensorName::parse("bar").unwrap(),
            TensorName { node: "bar".into(), port: 0 }
        );
        assert!(TensorName::parse("bar:x").is_err());
    }

    #[test]
    fn reachability() {
        let (g, a, b, _c, d) = diamond();
        let r = g.reachable_from(&[b]);
        assert!(r.contains(&a) && r.contains(&b) && !r.contains(&d));
        let r2 = g.reachable_from(&[d]);
        assert_eq!(r2.len(), 4);
    }

    #[test]
    fn topo_order_respects_deps() {
        let (g, ..) = diamond();
        let order = g.topo_order().unwrap();
        let pos: HashMap<NodeId, usize> =
            order.iter().enumerate().map(|(i, &n)| (n, i)).collect();
        for id in g.ids() {
            for e in &g.node(id).inputs {
                assert!(pos[&e.node] < pos[&id]);
            }
        }
    }

    #[test]
    fn cycle_detected() {
        // Build a 2-cycle via control edges by hand (bypassing add()'s
        // forward-reference check with a two-step construction).
        let mut g = Graph::new();
        let a = g.add(simple_node("a", "NoOp", vec![])).unwrap();
        let b = g.add(simple_node("b", "NoOp", vec![])).unwrap();
        g.node_mut(a).control_inputs.push(b);
        g.node_mut(b).control_inputs.push(a);
        assert!(g.topo_order().is_err());
    }

    #[test]
    fn nextiteration_backedge_allowed() {
        let mut g = Graph::new();
        let m = g.add(simple_node("merge", "Merge", vec![])).unwrap();
        let n = g
            .add(simple_node("next", "NextIteration", vec![m.into()]))
            .unwrap();
        g.node_mut(m).inputs.push(n.into());
        assert!(g.topo_order().is_ok());
    }

    #[test]
    fn subgraph_remaps() {
        let (g, a, b, _c, _d) = diamond();
        let keep: HashSet<NodeId> = [a, b].into_iter().collect();
        let (sub, remap) = g.subgraph(&keep);
        assert_eq!(sub.len(), 2);
        let nb = sub.node(remap[&b]);
        assert_eq!(nb.inputs[0].node, remap[&a]);
    }

    #[test]
    fn fanout_correct() {
        let (g, a, b, c, d) = diamond();
        let f = g.fanout();
        let mut consumers: Vec<NodeId> = f.data[a.0].iter().map(|&(n, _)| n).collect();
        consumers.sort();
        assert_eq!(consumers, vec![b, c]);
        assert_eq!(f.data[d.0].len(), 0);
    }

    #[test]
    fn dump_contains_structure() {
        let (g, ..) = diamond();
        let d = g.dump();
        assert!(d.contains("d = Add(b, c)"));
    }
}
