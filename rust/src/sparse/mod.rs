//! Sparse embedding subsystem (§3's embedding examples, §4.2's sparse
//! gradients).
//!
//! Three pieces:
//!
//! * [`IndexedSlices`] — the graph-level sparse-gradient value. The
//!   gradient of `Gather` w.r.t. its params is "rows `indices` of the
//!   params receive `values`"; materializing that as a dense zeros-like
//!   of the table defeats the point of an embedding. Gradient functions
//!   that produce one return a *lazy dense handle* (a `SparseToDense`
//!   node) and record the (indices, values) twins in
//!   [`GraphBuilder::sparse_grads`]. Because the executor only runs
//!   fetched subgraphs, a sparse-aware consumer (the distributed
//!   trainer) fetches the twins and the densify node never executes;
//!   a dense consumer just uses the handle and gets correct values.
//! * [`ShardedTable`] — a `[vocab, dim]` embedding table mod-sharded
//!   over `num_shards` variables (shard `j` holds global rows with
//!   `id % num_shards == j` at local row `id / num_shards`, the
//!   paper's §4.2 "partitioned across several parameter server tasks").
//!   [`ShardedTable::lookup`] compiles ids → `ModShard` →
//!   `DynamicPartition` → per-shard `Gather` → `DynamicStitch`, so each
//!   shard's gradient is an `IndexedSlices` over *local* rows of that
//!   shard alone.
//! * [`sampled_softmax`] — the large-vocabulary loss: one positive
//!   logit per example plus `num_sampled` shared negatives drawn from
//!   `Pcg32::new(seed ^ step_id)`, so the forward and gradient kernels
//!   re-draw identical negatives within a step without a side channel.
//!
//! Bitwise-parity contract: applying an `IndexedSlices` gradient
//! per-row (scatter-SGD) is bit-identical to densify-then-apply *when
//! each row appears at most once in `indices`*. With duplicates the
//! dense path sums contributions before the update while the scatter
//! path applies them per occurrence — equal in exact arithmetic,
//! different in f32 rounding. Lookups with unique ids per step (and
//! disjoint rows across replicas) keep the strong contract.

use crate::error::{Result, Status};
use crate::graph::Endpoint;
use crate::ops::builder::GraphBuilder;
use crate::tensor::{DType, Tensor};

/// A sparse gradient: `values` holds `len(indices)` rows destined for
/// the rows `indices` of some `[rows, …]` tensor. Duplicate indices are
/// allowed and mean "sum" (densify) / "apply per occurrence" (scatter).
///
/// This is the *graph-level* view — two endpoints into the gradient
/// subgraph. Runtime tensors flow only when a consumer fetches them.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct IndexedSlices {
    /// i64 (or i32) index vector, one entry per value row.
    pub indices: Endpoint,
    /// f32 values, flat layout `[len(indices), row…]`.
    pub values: Endpoint,
}

/// The sparse twin of gradient endpoint `g`, if it has one.
pub fn as_sparse(b: &GraphBuilder, g: Endpoint) -> Option<IndexedSlices> {
    b.sparse_grads.get(&g).copied()
}

/// Rows held by shard `j` of a `vocab`-row table mod-sharded `shards`
/// ways: `ceil((vocab - j) / shards)`.
pub fn shard_rows(vocab: usize, shards: usize, j: usize) -> usize {
    (vocab.saturating_sub(j) + shards - 1) / shards
}

/// An embedding table mod-sharded into per-shard `Variable`s.
pub struct ShardedTable {
    pub name: String,
    pub vocab: usize,
    pub dim: usize,
    /// Per-shard variable endpoints; shard `j` is `[shard_rows(j), dim]`.
    pub shards: Vec<Endpoint>,
}

impl ShardedTable {
    /// Create per-shard variables `{name}/shard{j}` by mod-sharding the
    /// rows of `init` (`[vocab, dim]`). Sharding a table this way is
    /// bit-identical to one unsharded variable initialized with `init`:
    /// the rows are the same f32 words, just re-homed.
    pub fn new(
        b: &mut GraphBuilder,
        name: &str,
        init: Tensor,
        num_shards: usize,
    ) -> Result<ShardedTable> {
        if num_shards == 0 {
            return Err(Status::invalid_argument("ShardedTable: num_shards must be >= 1"));
        }
        let dims = init.shape().dims();
        if dims.len() != 2 || dims[1] == 0 {
            return Err(Status::invalid_argument(format!(
                "ShardedTable: init must be [vocab, dim>0], got {:?}",
                dims
            )));
        }
        let (vocab, dim) = (dims[0], dims[1]);
        let v = init.as_f32()?;
        let mut shards = Vec::with_capacity(num_shards);
        for j in 0..num_shards {
            let mut rows = Vec::with_capacity(shard_rows(vocab, num_shards, j) * dim);
            let mut r = j;
            while r < vocab {
                rows.extend_from_slice(&v[r * dim..(r + 1) * dim]);
                r += num_shards;
            }
            let t = Tensor::from_f32(vec![rows.len() / dim, dim], rows)?;
            shards.push(b.variable(&format!("{name}/shard{j}"), t)?);
        }
        Ok(ShardedTable { name: name.to_string(), vocab, dim, shards })
    }

    /// Embedding lookup for an i64/i32 id vector: partition ids by
    /// shard, `Gather` each shard, `DynamicStitch` the rows back into
    /// id order. Output is `[len(ids), dim]`, bit-identical to
    /// `Gather(unsharded_table, ids)`.
    ///
    /// Differentiating through the result yields one [`IndexedSlices`]
    /// per shard variable, indexed by that shard's *local* rows.
    pub fn lookup(&self, b: &mut GraphBuilder, ids: Endpoint) -> Result<Endpoint> {
        let n = self.shards.len() as i64;
        b.with_scope(&self.name.clone(), |b| {
            let ms = b.op("ModShard", "modshard", vec![ids], vec![("shards", n.into())])?;
            let parts = Endpoint::new(ms, 0);
            let locals = Endpoint::new(ms, 1);
            // Row positions 0..len(ids), partitioned the same way, drive
            // the stitch back into request order.
            let pos = b.op1("RowIds", "pos", vec![ids], vec![])?;
            let loc_parts = b.op(
                "DynamicPartition",
                "part_locals",
                vec![locals, parts],
                vec![("num_partitions", n.into())],
            )?;
            let pos_parts = b.op(
                "DynamicPartition",
                "part_pos",
                vec![pos, parts],
                vec![("num_partitions", n.into())],
            )?;
            let mut stitch_in: Vec<Endpoint> =
                (0..self.shards.len()).map(|j| Endpoint::new(pos_parts, j)).collect();
            for (j, &shard) in self.shards.iter().enumerate() {
                stitch_in.push(b.op1(
                    "Gather",
                    "shard_gather",
                    vec![shard, Endpoint::new(loc_parts, j)],
                    vec![],
                )?);
            }
            b.op1("DynamicStitch", "stitch", stitch_in, vec![("N", n.into())])
        })
    }
}

/// Sampled-softmax loss `[batch]` for `emb [batch, dim]`, `weights
/// [vocab, dim]`, `labels [batch]` (i64/i32): the per-example softmax
/// cross-entropy over `1 + num_sampled` logits — the true label's
/// column plus `num_sampled` negatives shared across the batch,
/// re-drawn per step from `Pcg32::new(seed ^ step_id)`.
///
/// The estimator is biased (uniform sampling with replacement, no
/// rejection of accidental hits on the true label); it converges to the
/// full softmax as `num_sampled → vocab`. Differentiating yields a
/// dense gradient for `emb` and an [`IndexedSlices`] over at most
/// `batch + num_sampled` rows for `weights`.
///
/// Fetch the loss and its gradients in the *same* `Session::run`: the
/// two kernels agree on negatives only within one step.
pub fn sampled_softmax(
    b: &mut GraphBuilder,
    emb: Endpoint,
    weights: Endpoint,
    labels: Endpoint,
    num_sampled: i64,
    seed: i64,
) -> Result<Endpoint> {
    b.op1(
        "SampledSoftmax",
        "sampled_softmax",
        vec![emb, weights, labels],
        vec![("num_sampled", num_sampled.into()), ("seed", seed.into())],
    )
}

/// Build ids as an i64 constant — the common feed for lookups in tests
/// and examples.
pub fn ids_const(b: &mut GraphBuilder, ids: Vec<i64>) -> Endpoint {
    let n = ids.len();
    b.constant(Tensor::from_i64(vec![n], ids).expect("vector shape always fits"))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::session::{Session, SessionOptions};
    use crate::util::rng::Pcg32;

    fn random_table(vocab: usize, dim: usize, seed: u64) -> Tensor {
        let mut rng = Pcg32::new(seed);
        let v: Vec<f32> = (0..vocab * dim).map(|_| rng.normal()).collect();
        Tensor::from_f32(vec![vocab, dim], v).unwrap()
    }

    fn fetch(b: GraphBuilder, e: Endpoint) -> Tensor {
        let name = format!("{}:{}", b.graph.node(e.node).name, e.port);
        let init: Vec<String> =
            b.init_ops.iter().map(|&id| b.graph.node(id).name.clone()).collect();
        let sess = Session::new(b.into_graph(), SessionOptions::default());
        let init_refs: Vec<&str> = init.iter().map(|s| s.as_str()).collect();
        sess.run_targets(&init_refs).unwrap();
        sess.run(&[], &[&name], &[]).unwrap().remove(0)
    }

    #[test]
    fn shard_rows_partitions_vocab() {
        for (vocab, shards) in [(10, 3), (7, 7), (5, 8), (100, 1)] {
            let total: usize = (0..shards).map(|j| shard_rows(vocab, shards, j)).sum();
            assert_eq!(total, vocab, "vocab {vocab} shards {shards}");
        }
    }

    #[test]
    fn sharded_lookup_matches_unsharded_bitwise() {
        let table = random_table(11, 4, 99);
        let ids = vec![3i64, 0, 10, 7, 3, 5];

        let mut b = GraphBuilder::new();
        let var = b.variable("table", table.clone()).unwrap();
        let idc = ids_const(&mut b, ids.clone());
        let dense = b.op1("Gather", "lookup", vec![var, idc], vec![]).unwrap();
        let want = fetch(b, dense);

        for shards in [1, 2, 3] {
            let mut b = GraphBuilder::new();
            let t = ShardedTable::new(&mut b, "emb", table.clone(), shards).unwrap();
            let idc = ids_const(&mut b, ids.clone());
            let out = t.lookup(&mut b, idc).unwrap();
            let got = fetch(b, out);
            assert_eq!(got.shape().dims(), want.shape().dims(), "{shards} shards");
            assert_eq!(got.as_f32().unwrap(), want.as_f32().unwrap(), "{shards} shards");
        }
    }

    #[test]
    fn lookup_gradient_is_sparse_per_shard() {
        let mut b = GraphBuilder::new();
        let t = ShardedTable::new(&mut b, "emb", random_table(9, 3, 1), 3).unwrap();
        let idc = ids_const(&mut b, vec![1, 4, 8]);
        let rows = t.lookup(&mut b, idc).unwrap();
        let loss = b.reduce_sum(rows, None);
        let grads = crate::autodiff::gradients(&mut b, loss, &t.shards).unwrap();
        for (j, g) in grads.iter().enumerate() {
            let g = g.unwrap_or_else(|| panic!("shard {j} should have a gradient"));
            let s = as_sparse(&b, g).unwrap_or_else(|| panic!("shard {j} grad should be sparse"));
            assert_eq!(b.graph.node(g.node).op, "SparseToDense");
            assert_ne!(s.indices, s.values);
        }
    }

    #[test]
    fn hostile_table_shapes_rejected() {
        let mut b = GraphBuilder::new();
        let bad = Tensor::from_f32(vec![6], vec![0.0; 6]).unwrap();
        assert!(ShardedTable::new(&mut b, "e", bad, 2).is_err(), "rank-1 init");
        let ok = Tensor::from_f32(vec![3, 2], vec![0.0; 6]).unwrap();
        assert!(ShardedTable::new(&mut b, "e", ok, 0).is_err(), "zero shards");
    }
}
