//! Tensor element types (§3 "Tensors": "signed and unsigned integers
//! ranging in size from 8 bits to 64 bits, IEEE float and double types,
//! a complex number type, and a string type"). We implement the subset the
//! rest of the system exercises; the registry rejects ops instantiated at
//! unsupported types with `Unimplemented`, the same behaviour a TF binary
//! without a registered kernel exhibits.

use crate::error::{Result, Status};

/// Element type of a tensor. Order is wire-format-stable (checkpoints and
/// the distributed proto encode `as_u8`).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum DType {
    F32,
    F64,
    I32,
    I64,
    U8,
    Bool,
    Str,
    /// Truncated 16-bit float used by the §5.5 lossy wire compression.
    /// Never a kernel compute type — it exists only inside Send/Recv.
    BF16,
}

impl DType {
    pub fn as_u8(self) -> u8 {
        match self {
            DType::F32 => 0,
            DType::F64 => 1,
            DType::I32 => 2,
            DType::I64 => 3,
            DType::U8 => 4,
            DType::Bool => 5,
            DType::Str => 6,
            DType::BF16 => 7,
        }
    }

    pub fn from_u8(v: u8) -> Result<DType> {
        Ok(match v {
            0 => DType::F32,
            1 => DType::F64,
            2 => DType::I32,
            3 => DType::I64,
            4 => DType::U8,
            5 => DType::Bool,
            6 => DType::Str,
            7 => DType::BF16,
            _ => return Err(Status::invalid_argument(format!("unknown dtype byte {v}"))),
        })
    }

    /// Bytes per element (strings report their pointer-free estimate of 16;
    /// the cost model only needs an order of magnitude there).
    pub fn size_bytes(self) -> usize {
        match self {
            DType::F32 | DType::I32 => 4,
            DType::F64 | DType::I64 => 8,
            DType::U8 | DType::Bool => 1,
            DType::Str => 16,
            DType::BF16 => 2,
        }
    }

    pub fn is_floating(self) -> bool {
        matches!(self, DType::F32 | DType::F64 | DType::BF16)
    }

    pub fn is_integer(self) -> bool {
        matches!(self, DType::I32 | DType::I64 | DType::U8)
    }

    pub fn name(self) -> &'static str {
        match self {
            DType::F32 => "float32",
            DType::F64 => "float64",
            DType::I32 => "int32",
            DType::I64 => "int64",
            DType::U8 => "uint8",
            DType::Bool => "bool",
            DType::Str => "string",
            DType::BF16 => "bfloat16",
        }
    }
}

impl std::fmt::Display for DType {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(self.name())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const ALL: [DType; 8] = [
        DType::F32,
        DType::F64,
        DType::I32,
        DType::I64,
        DType::U8,
        DType::Bool,
        DType::Str,
        DType::BF16,
    ];

    #[test]
    fn byte_roundtrip() {
        for d in ALL {
            assert_eq!(DType::from_u8(d.as_u8()).unwrap(), d);
        }
        assert!(DType::from_u8(200).is_err());
    }

    #[test]
    fn classification() {
        assert!(DType::F32.is_floating());
        assert!(!DType::F32.is_integer());
        assert!(DType::I64.is_integer());
        assert!(!DType::Str.is_floating());
        assert!(!DType::Bool.is_integer());
    }

    #[test]
    fn sizes() {
        assert_eq!(DType::F32.size_bytes(), 4);
        assert_eq!(DType::BF16.size_bytes(), 2);
        assert_eq!(DType::Bool.size_bytes(), 1);
    }
}
