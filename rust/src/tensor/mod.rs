//! The tensor substrate (paper §3 "Tensors"): a typed, multidimensional
//! array whose backing store is reference counted ("Tensor backing store
//! buffers are reference counted and are deallocated when no references
//! remain") — here an `Arc<TensorData>`, so tensor clones and Send/Recv
//! handoffs never copy element data.

pub mod buffer;
pub mod codec;
pub mod dtype;
pub mod shape;

pub use buffer::{BufRecycler, TensorBuffer};
pub use dtype::DType;
pub use shape::Shape;

use crate::error::{Result, Status};
use std::sync::Arc;

/// Type-erased element storage. One variant per supported `DType`.
#[derive(Debug, Clone, PartialEq)]
pub enum TensorData {
    F32(Vec<f32>),
    F64(Vec<f64>),
    I32(Vec<i32>),
    I64(Vec<i64>),
    U8(Vec<u8>),
    Bool(Vec<bool>),
    Str(Vec<String>),
    /// Raw bf16 payload (upper 16 bits of an f32), §5.5 wire format only.
    BF16(Vec<u16>),
}

impl TensorData {
    pub fn dtype(&self) -> DType {
        match self {
            TensorData::F32(_) => DType::F32,
            TensorData::F64(_) => DType::F64,
            TensorData::I32(_) => DType::I32,
            TensorData::I64(_) => DType::I64,
            TensorData::U8(_) => DType::U8,
            TensorData::Bool(_) => DType::Bool,
            TensorData::Str(_) => DType::Str,
            TensorData::BF16(_) => DType::BF16,
        }
    }

    pub fn len(&self) -> usize {
        match self {
            TensorData::F32(v) => v.len(),
            TensorData::F64(v) => v.len(),
            TensorData::I32(v) => v.len(),
            TensorData::I64(v) => v.len(),
            TensorData::U8(v) => v.len(),
            TensorData::Bool(v) => v.len(),
            TensorData::Str(v) => v.len(),
            TensorData::BF16(v) => v.len(),
        }
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }
}

/// A typed, multidimensional array with shared backing store.
#[derive(Debug, Clone)]
pub struct Tensor {
    shape: Shape,
    buf: TensorBuffer,
}

impl Tensor {
    // ---- constructors -------------------------------------------------

    pub fn new(shape: impl Into<Shape>, data: TensorData) -> Result<Tensor> {
        Tensor::with_buffer(shape, TensorBuffer::owned(data))
    }

    /// Construct over an existing buffer (possibly arena-recycled; see
    /// [`TensorBuffer`]). The shape must match the element count.
    pub fn with_buffer(shape: impl Into<Shape>, buf: TensorBuffer) -> Result<Tensor> {
        let shape = shape.into();
        if shape.num_elements() != buf.data().len() {
            return Err(Status::invalid_argument(format!(
                "shape {shape} needs {} elements, data has {}",
                shape.num_elements(),
                buf.data().len()
            )));
        }
        Ok(Tensor { shape, buf })
    }

    pub fn from_f32(shape: impl Into<Shape>, v: Vec<f32>) -> Result<Tensor> {
        Tensor::new(shape, TensorData::F32(v))
    }

    pub fn from_f64(shape: impl Into<Shape>, v: Vec<f64>) -> Result<Tensor> {
        Tensor::new(shape, TensorData::F64(v))
    }

    pub fn from_i32(shape: impl Into<Shape>, v: Vec<i32>) -> Result<Tensor> {
        Tensor::new(shape, TensorData::I32(v))
    }

    pub fn from_i64(shape: impl Into<Shape>, v: Vec<i64>) -> Result<Tensor> {
        Tensor::new(shape, TensorData::I64(v))
    }

    pub fn from_bool(shape: impl Into<Shape>, v: Vec<bool>) -> Result<Tensor> {
        Tensor::new(shape, TensorData::Bool(v))
    }

    pub fn scalar_f32(v: f32) -> Tensor {
        Tensor::from_f32(Shape::scalar(), vec![v]).unwrap()
    }

    pub fn scalar_i32(v: i32) -> Tensor {
        Tensor::from_i32(Shape::scalar(), vec![v]).unwrap()
    }

    pub fn scalar_i64(v: i64) -> Tensor {
        Tensor::from_i64(Shape::scalar(), vec![v]).unwrap()
    }

    pub fn scalar_bool(v: bool) -> Tensor {
        Tensor::from_bool(Shape::scalar(), vec![v]).unwrap()
    }

    pub fn scalar_str(v: impl Into<String>) -> Tensor {
        Tensor::new(Shape::scalar(), TensorData::Str(vec![v.into()])).unwrap()
    }

    /// All-zeros tensor of the given dtype/shape.
    pub fn zeros(dtype: DType, shape: impl Into<Shape>) -> Result<Tensor> {
        let shape = shape.into();
        let n = shape.num_elements();
        let data = match dtype {
            DType::F32 => TensorData::F32(vec![0.0; n]),
            DType::F64 => TensorData::F64(vec![0.0; n]),
            DType::I32 => TensorData::I32(vec![0; n]),
            DType::I64 => TensorData::I64(vec![0; n]),
            DType::U8 => TensorData::U8(vec![0; n]),
            DType::Bool => TensorData::Bool(vec![false; n]),
            DType::Str => TensorData::Str(vec![String::new(); n]),
            DType::BF16 => TensorData::BF16(vec![0; n]),
        };
        Tensor::new(shape, data)
    }

    /// Constant-filled f32 tensor.
    pub fn fill_f32(shape: impl Into<Shape>, v: f32) -> Tensor {
        let shape = shape.into();
        let n = shape.num_elements();
        Tensor::from_f32(shape, vec![v; n]).unwrap()
    }

    // ---- accessors -----------------------------------------------------

    pub fn shape(&self) -> &Shape {
        &self.shape
    }

    pub fn dtype(&self) -> DType {
        self.data().dtype()
    }

    pub fn num_elements(&self) -> usize {
        self.shape.num_elements()
    }

    /// Approximate size in bytes (what the §3.2.1 cost model and §5.5
    /// compression accounting use).
    pub fn size_bytes(&self) -> usize {
        match self.data() {
            TensorData::Str(v) => v.iter().map(|s| s.len() + 8).sum(),
            d => d.len() * d.dtype().size_bytes(),
        }
    }

    pub fn data(&self) -> &TensorData {
        self.buf.data()
    }

    /// Number of outstanding references to the backing store.
    pub fn ref_count(&self) -> usize {
        self.buf.strong_count()
    }

    /// Take unique ownership of shape, storage, and recycler hook. Fails
    /// (returning `self` unchanged) when any other reference to the
    /// backing store exists — the guard that makes the executor's in-place
    /// kernel forwarding safe.
    pub fn try_into_parts(
        self,
    ) -> std::result::Result<(Shape, TensorData, Option<Arc<dyn BufRecycler>>), Tensor> {
        let Tensor { shape, buf } = self;
        match buf.try_take() {
            Ok((data, recycler)) => Ok((shape, data, recycler)),
            Err(buf) => Err(Tensor { shape, buf }),
        }
    }

    pub fn as_f32(&self) -> Result<&[f32]> {
        match self.data() {
            TensorData::F32(v) => Ok(v),
            d => Err(Status::invalid_argument(format!("expected float32, got {}", d.dtype()))),
        }
    }

    pub fn as_f64(&self) -> Result<&[f64]> {
        match self.data() {
            TensorData::F64(v) => Ok(v),
            d => Err(Status::invalid_argument(format!("expected float64, got {}", d.dtype()))),
        }
    }

    pub fn as_i32(&self) -> Result<&[i32]> {
        match self.data() {
            TensorData::I32(v) => Ok(v),
            d => Err(Status::invalid_argument(format!("expected int32, got {}", d.dtype()))),
        }
    }

    pub fn as_i64(&self) -> Result<&[i64]> {
        match self.data() {
            TensorData::I64(v) => Ok(v),
            d => Err(Status::invalid_argument(format!("expected int64, got {}", d.dtype()))),
        }
    }

    pub fn as_u8(&self) -> Result<&[u8]> {
        match self.data() {
            TensorData::U8(v) => Ok(v),
            d => Err(Status::invalid_argument(format!("expected uint8, got {}", d.dtype()))),
        }
    }

    pub fn as_bool(&self) -> Result<&[bool]> {
        match self.data() {
            TensorData::Bool(v) => Ok(v),
            d => Err(Status::invalid_argument(format!("expected bool, got {}", d.dtype()))),
        }
    }

    pub fn as_str_slice(&self) -> Result<&[String]> {
        match self.data() {
            TensorData::Str(v) => Ok(v),
            d => Err(Status::invalid_argument(format!("expected string, got {}", d.dtype()))),
        }
    }

    pub fn as_bf16_raw(&self) -> Result<&[u16]> {
        match self.data() {
            TensorData::BF16(v) => Ok(v),
            d => Err(Status::invalid_argument(format!("expected bfloat16, got {}", d.dtype()))),
        }
    }

    /// Scalar extraction helpers.
    pub fn scalar_value_f32(&self) -> Result<f32> {
        let v = self.as_f32()?;
        if v.len() != 1 {
            return Err(Status::invalid_argument(format!(
                "expected scalar, got shape {}",
                self.shape
            )));
        }
        Ok(v[0])
    }

    pub fn scalar_value_bool(&self) -> Result<bool> {
        let v = self.as_bool()?;
        if v.len() != 1 {
            return Err(Status::invalid_argument(format!(
                "expected scalar, got shape {}",
                self.shape
            )));
        }
        Ok(v[0])
    }

    pub fn scalar_value_i32(&self) -> Result<i32> {
        let v = self.as_i32()?;
        if v.len() != 1 {
            return Err(Status::invalid_argument(format!(
                "expected scalar, got shape {}",
                self.shape
            )));
        }
        Ok(v[0])
    }

    pub fn scalar_value_i64(&self) -> Result<i64> {
        let v = self.as_i64()?;
        if v.len() != 1 {
            return Err(Status::invalid_argument(format!(
                "expected scalar, got shape {}",
                self.shape
            )));
        }
        Ok(v[0])
    }

    // ---- transformations ------------------------------------------------

    /// Reshape: shares the backing store (no copy).
    pub fn reshape(&self, shape: impl Into<Shape>) -> Result<Tensor> {
        let shape = shape.into();
        self.shape.check_same_elements(&shape)?;
        Ok(Tensor { shape, buf: self.buf.clone() })
    }

    /// Cast between numeric dtypes (copies).
    pub fn cast(&self, to: DType) -> Result<Tensor> {
        if to == self.dtype() {
            return Ok(self.clone());
        }
        let f64s: Vec<f64> = match self.data() {
            TensorData::F32(v) => v.iter().map(|&x| x as f64).collect(),
            TensorData::F64(v) => v.clone(),
            TensorData::I32(v) => v.iter().map(|&x| x as f64).collect(),
            TensorData::I64(v) => v.iter().map(|&x| x as f64).collect(),
            TensorData::U8(v) => v.iter().map(|&x| x as f64).collect(),
            TensorData::Bool(v) => v.iter().map(|&x| if x { 1.0 } else { 0.0 }).collect(),
            TensorData::Str(_) | TensorData::BF16(_) => {
                return Err(Status::unimplemented(format!(
                    "cast from {} to {to}",
                    self.dtype()
                )))
            }
        };
        let data = match to {
            DType::F32 => TensorData::F32(f64s.iter().map(|&x| x as f32).collect()),
            DType::F64 => TensorData::F64(f64s),
            DType::I32 => TensorData::I32(f64s.iter().map(|&x| x as i32).collect()),
            DType::I64 => TensorData::I64(f64s.iter().map(|&x| x as i64).collect()),
            DType::U8 => TensorData::U8(f64s.iter().map(|&x| x as u8).collect()),
            DType::Bool => TensorData::Bool(f64s.iter().map(|&x| x != 0.0).collect()),
            DType::Str | DType::BF16 => {
                return Err(Status::unimplemented(format!(
                    "cast from {} to {to}",
                    self.dtype()
                )))
            }
        };
        Tensor::new(self.shape.clone(), data)
    }

    /// Elementwise approximate equality for tests: exact for non-floats.
    pub fn allclose(&self, other: &Tensor, atol: f64, rtol: f64) -> bool {
        if self.shape != other.shape || self.dtype() != other.dtype() {
            return false;
        }
        match (self.data(), other.data()) {
            (TensorData::F32(a), TensorData::F32(b)) => a
                .iter()
                .zip(b)
                .all(|(&x, &y)| close(x as f64, y as f64, atol, rtol)),
            (TensorData::F64(a), TensorData::F64(b)) => {
                a.iter().zip(b).all(|(&x, &y)| close(x, y, atol, rtol))
            }
            (a, b) => a == b,
        }
    }

    // ---- batching (serving) --------------------------------------------
    //
    // The inference-serving layer (`crate::serving`) coalesces many small
    // client requests into one device step by packing their feed tensors
    // along axis 0 and unpacking fetch tensors back per request. These
    // helpers are the pack/unpack primitives; they copy element data once.

    /// Stack `parts` along a new leading axis: n tensors of shape `S`
    /// become one tensor of shape `[n, S…]`. All parts must share dtype
    /// and shape.
    pub fn stack(parts: &[Tensor]) -> Result<Tensor> {
        if parts.is_empty() {
            return Err(Status::invalid_argument("stack of zero tensors"));
        }
        for p in &parts[1..] {
            if p.shape() != parts[0].shape() {
                return Err(Status::invalid_argument(format!(
                    "stack of mismatched shapes {} and {}",
                    parts[0].shape(),
                    p.shape()
                )));
            }
        }
        let mut dims = vec![parts.len()];
        dims.extend_from_slice(parts[0].shape().dims());
        let data = concat_data(parts)?;
        Tensor::new(dims, data)
    }

    /// Concatenate along the existing axis 0: tensors of shapes
    /// `[r_i, S…]` become one tensor of shape `[Σr_i, S…]`. All parts
    /// must share dtype, rank ≥ 1, and trailing dims.
    pub fn concat_rows(parts: &[Tensor]) -> Result<Tensor> {
        if parts.is_empty() {
            return Err(Status::invalid_argument("concat of zero tensors"));
        }
        let mut rows = 0usize;
        for p in parts {
            if p.shape().is_scalar() {
                return Err(Status::invalid_argument(
                    "concat_rows needs rank >= 1 (no batch axis on a scalar)",
                ));
            }
            if p.shape().dims()[1..] != parts[0].shape().dims()[1..] {
                return Err(Status::invalid_argument(format!(
                    "concat_rows of mismatched trailing dims: {} vs {}",
                    parts[0].shape(),
                    p.shape()
                )));
            }
            rows += p.shape().dim(0);
        }
        let mut dims = vec![rows];
        dims.extend_from_slice(&parts[0].shape().dims()[1..]);
        let data = concat_data(parts)?;
        Tensor::new(dims, data)
    }

    /// Inverse of [`Tensor::concat_rows`]: split axis 0 into chunks of
    /// `rows[i]` rows each. `rows` must sum to `dim(0)`.
    pub fn split_rows(&self, rows: &[usize]) -> Result<Vec<Tensor>> {
        if self.shape.is_scalar() {
            return Err(Status::invalid_argument(format!(
                "split_rows on scalar tensor {self}"
            )));
        }
        let total: usize = rows.iter().sum();
        if total != self.shape.dim(0) {
            return Err(Status::invalid_argument(format!(
                "split_rows sizes sum to {total} but tensor has {} rows",
                self.shape.dim(0)
            )));
        }
        let row_size: usize = self.shape.dims()[1..].iter().product();
        let mut out = Vec::with_capacity(rows.len());
        let mut start = 0usize;
        for &r in rows {
            let mut dims = vec![r];
            dims.extend_from_slice(&self.shape.dims()[1..]);
            let data = slice_data(self.data(), start * row_size, r * row_size);
            out.push(Tensor::new(dims, data)?);
            start += r;
        }
        Ok(out)
    }

    /// Inverse of [`Tensor::stack`]: split axis 0 into `dim(0)` tensors
    /// of the trailing shape.
    pub fn unstack(&self) -> Result<Vec<Tensor>> {
        if self.shape.is_scalar() {
            return Err(Status::invalid_argument(format!(
                "unstack on scalar tensor {self}"
            )));
        }
        let trailing: Vec<usize> = self.shape.dims()[1..].to_vec();
        let row_size: usize = trailing.iter().product();
        (0..self.shape.dim(0))
            .map(|i| {
                let data = slice_data(self.data(), i * row_size, row_size);
                Tensor::new(trailing.clone(), data)
            })
            .collect()
    }

    /// Any non-finite float elements? (§6 lesson 5 "guard against
    /// numerical errors" — the CheckNumerics op uses this.)
    pub fn has_non_finite(&self) -> bool {
        match self.data() {
            TensorData::F32(v) => v.iter().any(|x| !x.is_finite()),
            TensorData::F64(v) => v.iter().any(|x| !x.is_finite()),
            _ => false,
        }
    }
}

/// Concatenate the element storage of `parts` (dtypes must all match).
fn concat_data(parts: &[Tensor]) -> Result<TensorData> {
    macro_rules! cat {
        ($variant:ident) => {{
            let mut out = Vec::with_capacity(parts.iter().map(|p| p.num_elements()).sum());
            for p in parts {
                match p.data() {
                    TensorData::$variant(v) => out.extend_from_slice(v),
                    other => {
                        return Err(Status::invalid_argument(format!(
                            "cannot batch {} tensor with {} tensor",
                            other.dtype(),
                            parts[0].dtype()
                        )))
                    }
                }
            }
            TensorData::$variant(out)
        }};
    }
    Ok(match parts[0].data() {
        TensorData::F32(_) => cat!(F32),
        TensorData::F64(_) => cat!(F64),
        TensorData::I32(_) => cat!(I32),
        TensorData::I64(_) => cat!(I64),
        TensorData::U8(_) => cat!(U8),
        TensorData::Bool(_) => cat!(Bool),
        TensorData::Str(_) => cat!(Str),
        TensorData::BF16(_) => cat!(BF16),
    })
}

/// Copy `len` elements starting at `start` out of `data`.
fn slice_data(data: &TensorData, start: usize, len: usize) -> TensorData {
    match data {
        TensorData::F32(v) => TensorData::F32(v[start..start + len].to_vec()),
        TensorData::F64(v) => TensorData::F64(v[start..start + len].to_vec()),
        TensorData::I32(v) => TensorData::I32(v[start..start + len].to_vec()),
        TensorData::I64(v) => TensorData::I64(v[start..start + len].to_vec()),
        TensorData::U8(v) => TensorData::U8(v[start..start + len].to_vec()),
        TensorData::Bool(v) => TensorData::Bool(v[start..start + len].to_vec()),
        TensorData::Str(v) => TensorData::Str(v[start..start + len].to_vec()),
        TensorData::BF16(v) => TensorData::BF16(v[start..start + len].to_vec()),
    }
}

fn close(x: f64, y: f64, atol: f64, rtol: f64) -> bool {
    if x.is_nan() && y.is_nan() {
        return true;
    }
    (x - y).abs() <= atol + rtol * y.abs()
}

impl PartialEq for Tensor {
    fn eq(&self, other: &Self) -> bool {
        self.shape == other.shape && self.data() == other.data()
    }
}

impl std::fmt::Display for Tensor {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "Tensor<{} {}>", self.dtype(), self.shape)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn construct_and_access() {
        let t = Tensor::from_f32(vec![2, 3], vec![1., 2., 3., 4., 5., 6.]).unwrap();
        assert_eq!(t.dtype(), DType::F32);
        assert_eq!(t.num_elements(), 6);
        assert_eq!(t.size_bytes(), 24);
        assert_eq!(t.as_f32().unwrap()[4], 5.0);
        assert!(t.as_i32().is_err());
    }

    #[test]
    fn shape_mismatch_rejected() {
        assert!(Tensor::from_f32(vec![2, 2], vec![1., 2., 3.]).is_err());
    }

    #[test]
    fn reshape_shares_buffer() {
        let t = Tensor::from_f32(vec![2, 3], vec![0.0; 6]).unwrap();
        let r = t.reshape(vec![3, 2]).unwrap();
        assert_eq!(r.shape(), &Shape(vec![3, 2]));
        assert_eq!(t.ref_count(), 2);
        assert!(t.reshape(vec![5]).is_err());
    }

    #[test]
    fn clone_is_refcounted() {
        let t = Tensor::from_f32(vec![4], vec![1., 2., 3., 4.]).unwrap();
        assert_eq!(t.ref_count(), 1);
        let u = t.clone();
        assert_eq!(t.ref_count(), 2);
        drop(u);
        assert_eq!(t.ref_count(), 1);
    }

    #[test]
    fn cast_roundtrip() {
        let t = Tensor::from_i32(vec![3], vec![1, 2, 3]).unwrap();
        let f = t.cast(DType::F32).unwrap();
        assert_eq!(f.as_f32().unwrap(), &[1.0, 2.0, 3.0]);
        let back = f.cast(DType::I32).unwrap();
        assert_eq!(back.as_i32().unwrap(), &[1, 2, 3]);
        let b = t.cast(DType::Bool).unwrap();
        assert_eq!(b.as_bool().unwrap(), &[true, true, true]);
    }

    #[test]
    fn zeros_all_dtypes() {
        for d in [DType::F32, DType::F64, DType::I32, DType::I64, DType::U8, DType::Bool] {
            let t = Tensor::zeros(d, vec![2, 2]).unwrap();
            assert_eq!(t.dtype(), d);
            assert_eq!(t.num_elements(), 4);
        }
    }

    #[test]
    fn allclose_tolerances() {
        let a = Tensor::from_f32(vec![2], vec![1.0, 2.0]).unwrap();
        let b = Tensor::from_f32(vec![2], vec![1.0 + 1e-7, 2.0 - 1e-7]).unwrap();
        assert!(a.allclose(&b, 1e-5, 1e-5));
        let c = Tensor::from_f32(vec![2], vec![1.1, 2.0]).unwrap();
        assert!(!a.allclose(&c, 1e-5, 1e-5));
        // Shape mismatch
        let d = Tensor::from_f32(vec![1, 2], vec![1.0, 2.0]).unwrap();
        assert!(!a.allclose(&d, 1e-5, 1e-5));
    }

    #[test]
    fn non_finite_detection() {
        let t = Tensor::from_f32(vec![2], vec![1.0, f32::NAN]).unwrap();
        assert!(t.has_non_finite());
        let u = Tensor::from_f32(vec![2], vec![1.0, 2.0]).unwrap();
        assert!(!u.has_non_finite());
        let inf = Tensor::from_f32(vec![1], vec![f32::INFINITY]).unwrap();
        assert!(inf.has_non_finite());
    }

    #[test]
    fn scalar_helpers() {
        assert_eq!(Tensor::scalar_f32(3.5).scalar_value_f32().unwrap(), 3.5);
        assert_eq!(Tensor::scalar_bool(true).scalar_value_bool().unwrap(), true);
        assert_eq!(Tensor::scalar_i64(-7).scalar_value_i64().unwrap(), -7);
        let v = Tensor::from_f32(vec![2], vec![1., 2.]).unwrap();
        assert!(v.scalar_value_f32().is_err());
    }

    #[test]
    fn stack_and_unstack_roundtrip() {
        let a = Tensor::from_f32(vec![2], vec![1.0, 2.0]).unwrap();
        let b = Tensor::from_f32(vec![2], vec![3.0, 4.0]).unwrap();
        let s = Tensor::stack(&[a.clone(), b.clone()]).unwrap();
        assert_eq!(s.shape().dims(), &[2, 2]);
        assert_eq!(s.as_f32().unwrap(), &[1.0, 2.0, 3.0, 4.0]);
        let parts = s.unstack().unwrap();
        assert_eq!(parts.len(), 2);
        assert_eq!(parts[0], a);
        assert_eq!(parts[1], b);
        // Shape mismatch rejected.
        let c = Tensor::from_f32(vec![3], vec![0.0; 3]).unwrap();
        assert!(Tensor::stack(&[a, c]).is_err());
        assert!(Tensor::stack(&[]).is_err());
    }

    #[test]
    fn concat_and_split_rows_roundtrip() {
        let a = Tensor::from_f32(vec![1, 3], vec![1.0, 2.0, 3.0]).unwrap();
        let b = Tensor::from_f32(vec![2, 3], vec![4.0, 5.0, 6.0, 7.0, 8.0, 9.0]).unwrap();
        let cat = Tensor::concat_rows(&[a.clone(), b.clone()]).unwrap();
        assert_eq!(cat.shape().dims(), &[3, 3]);
        let parts = cat.split_rows(&[1, 2]).unwrap();
        assert_eq!(parts[0], a);
        assert_eq!(parts[1], b);
        // Row counts must cover the tensor.
        assert!(cat.split_rows(&[1, 1]).is_err());
        // Trailing-dim mismatch rejected.
        let c = Tensor::from_f32(vec![1, 2], vec![0.0; 2]).unwrap();
        assert!(Tensor::concat_rows(&[a.clone(), c]).is_err());
        // Scalars have no batch axis.
        assert!(Tensor::concat_rows(&[Tensor::scalar_f32(1.0)]).is_err());
        assert!(Tensor::scalar_f32(1.0).split_rows(&[1]).is_err());
        // Dtype mismatch rejected.
        let i = Tensor::from_i32(vec![1, 3], vec![1, 2, 3]).unwrap();
        assert!(Tensor::concat_rows(&[a, i]).is_err());
    }

    #[test]
    fn concat_rows_non_f32_dtypes() {
        let a = Tensor::from_i64(vec![2], vec![1, 2]).unwrap();
        let b = Tensor::from_i64(vec![1], vec![3]).unwrap();
        let cat = Tensor::concat_rows(&[a, b]).unwrap();
        assert_eq!(cat.as_i64().unwrap(), &[1, 2, 3]);
        let s = Tensor::new(Shape::vector(2), TensorData::Str(vec!["x".into(), "y".into()]))
            .unwrap();
        let parts = s.split_rows(&[1, 1]).unwrap();
        assert_eq!(parts[1].as_str_slice().unwrap(), &["y".to_string()]);
    }

    #[test]
    fn string_tensor_size() {
        let t = Tensor::new(
            Shape::vector(2),
            TensorData::Str(vec!["ab".into(), "cdef".into()]),
        )
        .unwrap();
        assert_eq!(t.size_bytes(), 2 + 8 + 4 + 8);
    }
}
