//! The refcounted tensor backing store, factored out of [`Tensor`](crate::tensor::Tensor) so the
//! step memory planner (`crate::memory`) can recycle element storage.
//!
//! A [`TensorBuffer`] is an `Arc<TensorData>` plus an optional *recycler*
//! hook. Plain tensors (`TensorBuffer::owned`) behave exactly as before:
//! clones share the store, and the store is freed when the last clone
//! drops. Arena-backed tensors (`TensorBuffer::recycled`) instead hand
//! their storage back to the step arena slot they were carved from when
//! the last reference drops, so the next step (or a later node of the same
//! step) reuses the allocation instead of hitting the heap. Either way a
//! `Tensor` is a zero-copy view; kernels never need to know which kind
//! they were given.
//!
//! Recycling is driven purely by the refcount: storage returns to its slot
//! only once *no* reference remains, so a tensor that escapes the step (a
//! fetch held by a client, a queued element) simply delays reuse — it can
//! never be observed changing underneath a live view.

use super::TensorData;
use std::sync::Arc;

/// Destination for storage whose last reference dropped. Implemented by
/// the step arena's slots (`crate::memory::StepArena`).
pub trait BufRecycler: Send + Sync {
    fn recycle(&self, data: TensorData);
}

/// Refcounted element storage with an optional return-to-arena hook.
pub struct TensorBuffer {
    /// `Some` until drop (taken in `Drop`/`try_take`).
    data: Option<Arc<TensorData>>,
    recycler: Option<Arc<dyn BufRecycler>>,
}

impl TensorBuffer {
    /// Plain heap-owned storage (the default everywhere).
    pub fn owned(data: TensorData) -> TensorBuffer {
        TensorBuffer { data: Some(Arc::new(data)), recycler: None }
    }

    /// Storage that returns to `recycler` when the last reference drops.
    pub fn recycled(data: TensorData, recycler: Arc<dyn BufRecycler>) -> TensorBuffer {
        TensorBuffer { data: Some(Arc::new(data)), recycler: Some(recycler) }
    }

    /// Rebuild from parts taken by [`TensorBuffer::try_take`] (the
    /// in-place-forwarding path re-wraps mutated storage this way,
    /// preserving the recycler).
    pub fn from_parts(data: TensorData, recycler: Option<Arc<dyn BufRecycler>>) -> TensorBuffer {
        TensorBuffer { data: Some(Arc::new(data)), recycler }
    }

    pub fn data(&self) -> &TensorData {
        self.data.as_ref().expect("TensorBuffer accessed after take")
    }

    /// Outstanding references to the backing store.
    pub fn strong_count(&self) -> usize {
        self.data.as_ref().map(Arc::strong_count).unwrap_or(0)
    }

    pub fn recycler(&self) -> Option<&Arc<dyn BufRecycler>> {
        self.recycler.as_ref()
    }

    /// Take unique ownership of the storage (plus the recycler, so a
    /// rebuilt buffer keeps returning to its slot). Fails — returning the
    /// buffer unchanged — when any other reference exists, which makes
    /// in-place mutation of the extracted storage safe by construction.
    pub fn try_take(
        mut self,
    ) -> std::result::Result<(TensorData, Option<Arc<dyn BufRecycler>>), TensorBuffer> {
        let arc = self.data.take().expect("TensorBuffer accessed after take");
        match Arc::try_unwrap(arc) {
            Ok(owned) => Ok((owned, self.recycler.take())),
            Err(shared) => {
                self.data = Some(shared);
                Err(self)
            }
        }
    }
}

impl Clone for TensorBuffer {
    fn clone(&self) -> TensorBuffer {
        TensorBuffer { data: self.data.clone(), recycler: self.recycler.clone() }
    }
}

impl Drop for TensorBuffer {
    fn drop(&mut self) {
        if let (Some(arc), Some(recycler)) = (self.data.take(), self.recycler.take()) {
            if let Ok(owned) = Arc::try_unwrap(arc) {
                recycler.recycle(owned);
            }
        }
    }
}

impl std::fmt::Debug for TensorBuffer {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("TensorBuffer")
            .field("data", &self.data)
            .field("recycled", &self.recycler.is_some())
            .finish()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::Mutex;

    #[derive(Default)]
    struct Sink {
        got: Mutex<Vec<TensorData>>,
    }

    impl BufRecycler for Sink {
        fn recycle(&self, data: TensorData) {
            self.got.lock().unwrap().push(data);
        }
    }

    #[test]
    fn owned_buffer_never_recycles() {
        let b = TensorBuffer::owned(TensorData::F32(vec![1.0, 2.0]));
        assert_eq!(b.strong_count(), 1);
        let c = b.clone();
        assert_eq!(b.strong_count(), 2);
        drop(c);
        drop(b); // no panic, nothing to observe
    }

    #[test]
    fn recycled_buffer_returns_on_last_drop() {
        let sink = Arc::new(Sink::default());
        let b = TensorBuffer::recycled(TensorData::F32(vec![7.0; 4]), sink.clone());
        let c = b.clone();
        drop(b);
        assert!(sink.got.lock().unwrap().is_empty(), "recycled while a clone was live");
        drop(c);
        let got = sink.got.lock().unwrap();
        assert_eq!(got.len(), 1);
        assert_eq!(got[0], TensorData::F32(vec![7.0; 4]));
    }

    #[test]
    fn try_take_requires_unique_ownership() {
        let sink = Arc::new(Sink::default());
        let b = TensorBuffer::recycled(TensorData::F32(vec![1.0]), sink.clone());
        let c = b.clone();
        let b = b.try_take().expect_err("shared buffer must not be takeable");
        drop(c);
        let (data, recycler) = b.try_take().expect("unique now");
        assert_eq!(data, TensorData::F32(vec![1.0]));
        assert!(recycler.is_some());
        // Nothing was recycled: ownership moved out instead.
        assert!(sink.got.lock().unwrap().is_empty());
        // Rebuilding from parts restores the recycler chain.
        drop(TensorBuffer::from_parts(data, recycler));
        assert_eq!(sink.got.lock().unwrap().len(), 1);
    }
}
