//! Tensor shapes: arbitrary rank, row-major.
//!
//! Shapes here are always fully known at runtime (graph-construction-time
//! inference may carry unknown dims, represented by `None` in
//! `ops::shape_fn::PartialShape`).

use crate::error::{Result, Status};

/// A fully-defined shape.
#[derive(Debug, Clone, PartialEq, Eq, Hash, Default)]
pub struct Shape(pub Vec<usize>);

impl Shape {
    pub fn scalar() -> Shape {
        Shape(vec![])
    }

    pub fn vector(n: usize) -> Shape {
        Shape(vec![n])
    }

    pub fn matrix(r: usize, c: usize) -> Shape {
        Shape(vec![r, c])
    }

    pub fn rank(&self) -> usize {
        self.0.len()
    }

    pub fn dims(&self) -> &[usize] {
        &self.0
    }

    pub fn dim(&self, i: usize) -> usize {
        self.0[i]
    }

    pub fn num_elements(&self) -> usize {
        self.0.iter().product()
    }

    pub fn is_scalar(&self) -> bool {
        self.0.is_empty()
    }

    /// Row-major strides in elements.
    pub fn strides(&self) -> Vec<usize> {
        let mut strides = vec![0; self.0.len()];
        let mut acc = 1;
        for i in (0..self.0.len()).rev() {
            strides[i] = acc;
            acc *= self.0[i];
        }
        strides
    }

    /// Validate that `other` has the same number of elements (for Reshape).
    pub fn check_same_elements(&self, other: &Shape) -> Result<()> {
        if self.num_elements() != other.num_elements() {
            return Err(Status::invalid_argument(format!(
                "cannot reshape {self} ({} elements) to {other} ({} elements)",
                self.num_elements(),
                other.num_elements()
            )));
        }
        Ok(())
    }

    /// Numpy-style broadcast of two shapes; error if incompatible.
    pub fn broadcast(&self, other: &Shape) -> Result<Shape> {
        let rank = self.rank().max(other.rank());
        let mut out = vec![0usize; rank];
        for i in 0..rank {
            let a = if i < rank - self.rank() { 1 } else { self.0[i - (rank - self.rank())] };
            let b = if i < rank - other.rank() { 1 } else { other.0[i - (rank - other.rank())] };
            out[i] = if a == b {
                a
            } else if a == 1 {
                b
            } else if b == 1 {
                a
            } else {
                return Err(Status::invalid_argument(format!(
                    "shapes {self} and {other} are not broadcast-compatible"
                )));
            };
        }
        Ok(Shape(out))
    }
}

impl std::fmt::Display for Shape {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "[")?;
        for (i, d) in self.0.iter().enumerate() {
            if i > 0 {
                write!(f, ",")?;
            }
            write!(f, "{d}")?;
        }
        write!(f, "]")
    }
}

impl From<Vec<usize>> for Shape {
    fn from(v: Vec<usize>) -> Shape {
        Shape(v)
    }
}

impl From<&[usize]> for Shape {
    fn from(v: &[usize]) -> Shape {
        Shape(v.to_vec())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn basics() {
        let s = Shape::matrix(3, 4);
        assert_eq!(s.rank(), 2);
        assert_eq!(s.num_elements(), 12);
        assert_eq!(s.strides(), vec![4, 1]);
        assert!(Shape::scalar().is_scalar());
        assert_eq!(Shape::scalar().num_elements(), 1);
    }

    #[test]
    fn display() {
        assert_eq!(Shape::matrix(3, 4).to_string(), "[3,4]");
        assert_eq!(Shape::scalar().to_string(), "[]");
    }

    #[test]
    fn broadcast_rules() {
        let a = Shape(vec![4, 1]);
        let b = Shape(vec![3]);
        assert_eq!(a.broadcast(&b).unwrap(), Shape(vec![4, 3]));
        let c = Shape(vec![2, 3]);
        assert_eq!(c.broadcast(&Shape::scalar()).unwrap(), c);
        assert!(Shape(vec![2, 3]).broadcast(&Shape(vec![4])).is_err());
        assert_eq!(
            Shape(vec![5, 1, 7]).broadcast(&Shape(vec![5, 6, 1])).unwrap(),
            Shape(vec![5, 6, 7])
        );
    }

    #[test]
    fn reshape_check() {
        assert!(Shape(vec![2, 6]).check_same_elements(&Shape(vec![3, 4])).is_ok());
        assert!(Shape(vec![2, 6]).check_same_elements(&Shape(vec![5])).is_err());
    }

    #[test]
    fn strides_high_rank() {
        let s = Shape(vec![2, 3, 4]);
        assert_eq!(s.strides(), vec![12, 4, 1]);
    }
}
