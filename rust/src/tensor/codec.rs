//! Binary tensor wire/disk format, shared by the checkpoint bundle (§3.3
//! Fault Tolerance), the distributed rendezvous (§3.3), and the §5.5 lossy
//! compression path.
//!
//! Layout (little endian):
//!   u8  dtype
//!   u8  rank
//!   u64 × rank dims
//!   u64 payload element count
//!   payload (for Str: per-element u32 length + utf8 bytes)

use super::{DType, Shape, Tensor, TensorData};
use crate::error::{Result, Status};
use crate::util::byteorder::LittleEndian;

pub fn encode(t: &Tensor) -> Vec<u8> {
    let mut out = Vec::with_capacity(16 + t.size_bytes());
    out.push(t.dtype().as_u8());
    out.push(t.shape().rank() as u8);
    for &d in t.shape().dims() {
        let mut b = [0u8; 8];
        LittleEndian::write_u64(&mut b, d as u64);
        out.extend_from_slice(&b);
    }
    let n = t.num_elements() as u64;
    let mut b = [0u8; 8];
    LittleEndian::write_u64(&mut b, n);
    out.extend_from_slice(&b);
    match t.data() {
        TensorData::F32(v) => {
            for &x in v {
                let mut b = [0u8; 4];
                LittleEndian::write_f32(&mut b, x);
                out.extend_from_slice(&b);
            }
        }
        TensorData::F64(v) => {
            for &x in v {
                let mut b = [0u8; 8];
                LittleEndian::write_f64(&mut b, x);
                out.extend_from_slice(&b);
            }
        }
        TensorData::I32(v) => {
            for &x in v {
                let mut b = [0u8; 4];
                LittleEndian::write_i32(&mut b, x);
                out.extend_from_slice(&b);
            }
        }
        TensorData::I64(v) => {
            for &x in v {
                let mut b = [0u8; 8];
                LittleEndian::write_i64(&mut b, x);
                out.extend_from_slice(&b);
            }
        }
        TensorData::U8(v) => out.extend_from_slice(v),
        TensorData::Bool(v) => out.extend(v.iter().map(|&b| b as u8)),
        TensorData::Str(v) => {
            for s in v {
                let mut b = [0u8; 4];
                LittleEndian::write_u32(&mut b, s.len() as u32);
                out.extend_from_slice(&b);
                out.extend_from_slice(s.as_bytes());
            }
        }
        TensorData::BF16(v) => {
            for &x in v {
                let mut b = [0u8; 2];
                LittleEndian::write_u16(&mut b, x);
                out.extend_from_slice(&b);
            }
        }
    }
    out
}

/// Decode a tensor; returns (tensor, bytes consumed).
pub fn decode(buf: &[u8]) -> Result<(Tensor, usize)> {
    let need = |n: usize, at: usize| -> Result<()> {
        if buf.len() < at + n {
            return Err(Status::invalid_argument("truncated tensor encoding"));
        }
        Ok(())
    };
    need(2, 0)?;
    let dtype = DType::from_u8(buf[0])?;
    let rank = buf[1] as usize;
    let mut pos = 2;
    need(8 * rank + 8, pos)?;
    let mut dims = Vec::with_capacity(rank);
    for _ in 0..rank {
        dims.push(LittleEndian::read_u64(&buf[pos..pos + 8]) as usize);
        pos += 8;
    }
    let n = LittleEndian::read_u64(&buf[pos..pos + 8]) as usize;
    pos += 8;
    let shape = Shape(dims);
    if shape.num_elements() != n {
        return Err(Status::invalid_argument("tensor encoding: shape/count mismatch"));
    }
    let data = match dtype {
        DType::F32 => {
            need(4 * n, pos)?;
            let mut v = Vec::with_capacity(n);
            for i in 0..n {
                v.push(LittleEndian::read_f32(&buf[pos + 4 * i..]));
            }
            pos += 4 * n;
            TensorData::F32(v)
        }
        DType::F64 => {
            need(8 * n, pos)?;
            let mut v = Vec::with_capacity(n);
            for i in 0..n {
                v.push(LittleEndian::read_f64(&buf[pos + 8 * i..]));
            }
            pos += 8 * n;
            TensorData::F64(v)
        }
        DType::I32 => {
            need(4 * n, pos)?;
            let mut v = Vec::with_capacity(n);
            for i in 0..n {
                v.push(LittleEndian::read_i32(&buf[pos + 4 * i..]));
            }
            pos += 4 * n;
            TensorData::I32(v)
        }
        DType::I64 => {
            need(8 * n, pos)?;
            let mut v = Vec::with_capacity(n);
            for i in 0..n {
                v.push(LittleEndian::read_i64(&buf[pos + 8 * i..]));
            }
            pos += 8 * n;
            TensorData::I64(v)
        }
        DType::U8 => {
            need(n, pos)?;
            let v = buf[pos..pos + n].to_vec();
            pos += n;
            TensorData::U8(v)
        }
        DType::Bool => {
            need(n, pos)?;
            let v = buf[pos..pos + n].iter().map(|&b| b != 0).collect();
            pos += n;
            TensorData::Bool(v)
        }
        DType::Str => {
            let mut v = Vec::with_capacity(n);
            for _ in 0..n {
                need(4, pos)?;
                let len = LittleEndian::read_u32(&buf[pos..pos + 4]) as usize;
                pos += 4;
                need(len, pos)?;
                let s = std::str::from_utf8(&buf[pos..pos + len])
                    .map_err(|_| Status::invalid_argument("invalid utf8 in string tensor"))?;
                v.push(s.to_string());
                pos += len;
            }
            TensorData::Str(v)
        }
        DType::BF16 => {
            need(2 * n, pos)?;
            let mut v = Vec::with_capacity(n);
            for i in 0..n {
                v.push(LittleEndian::read_u16(&buf[pos + 2 * i..]));
            }
            pos += 2 * n;
            TensorData::BF16(v)
        }
    };
    Ok((Tensor::new(shape, data)?, pos))
}

#[cfg(test)]
mod tests {
    use super::*;

    fn roundtrip(t: &Tensor) {
        let enc = encode(t);
        let (dec, used) = decode(&enc).unwrap();
        assert_eq!(used, enc.len());
        assert_eq!(&dec, t);
    }

    #[test]
    fn roundtrip_all_dtypes() {
        roundtrip(&Tensor::from_f32(vec![2, 3], vec![1., -2., 3.5, 0., 5., 6.]).unwrap());
        roundtrip(&Tensor::from_f64(vec![2], vec![1.5, -0.25]).unwrap());
        roundtrip(&Tensor::from_i32(vec![3], vec![-1, 0, i32::MAX]).unwrap());
        roundtrip(&Tensor::from_i64(vec![1], vec![i64::MIN]).unwrap());
        roundtrip(&Tensor::new(Shape::vector(3), TensorData::U8(vec![0, 128, 255])).unwrap());
        roundtrip(&Tensor::from_bool(vec![2], vec![true, false]).unwrap());
        roundtrip(
            &Tensor::new(
                Shape::vector(2),
                TensorData::Str(vec!["hello".into(), "wörld".into()]),
            )
            .unwrap(),
        );
        roundtrip(&Tensor::new(Shape::vector(2), TensorData::BF16(vec![0x3f80, 0x4000])).unwrap());
        roundtrip(&Tensor::scalar_f32(42.0));
    }

    #[test]
    fn truncation_detected() {
        let enc = encode(&Tensor::from_f32(vec![4], vec![1., 2., 3., 4.]).unwrap());
        for cut in [0, 1, 5, enc.len() - 1] {
            assert!(decode(&enc[..cut]).is_err(), "cut={cut}");
        }
    }

    #[test]
    fn two_tensors_back_to_back() {
        let a = Tensor::scalar_i32(7);
        let b = Tensor::from_f32(vec![2], vec![1.0, 2.0]).unwrap();
        let mut buf = encode(&a);
        buf.extend(encode(&b));
        let (da, used) = decode(&buf).unwrap();
        let (db, used2) = decode(&buf[used..]).unwrap();
        assert_eq!(da, a);
        assert_eq!(db, b);
        assert_eq!(used + used2, buf.len());
    }
}
