//! Device names, e.g. `/job:worker/task:17/device:gpu:3` or
//! `/job:localhost/task:0/device:cpu:0` (§3 "Devices"), and partial
//! constraint specs like `/job:worker/task:17` or `/device:gpu:*` (§4.3).

use crate::error::{Result, Status};

/// A fully-qualified device name.
#[derive(Debug, Clone, PartialEq, Eq, Hash)]
pub struct DeviceSpec {
    pub job: String,
    pub task: usize,
    pub device_type: String, // lowercase, e.g. "cpu"
    pub index: usize,
}

impl DeviceSpec {
    pub fn new(job: &str, task: usize, device_type: &str, index: usize) -> DeviceSpec {
        DeviceSpec {
            job: job.to_string(),
            task,
            device_type: device_type.to_lowercase(),
            index,
        }
    }

    pub fn local_cpu(index: usize) -> DeviceSpec {
        DeviceSpec::new("localhost", 0, "cpu", index)
    }

    pub fn worker_cpu(task: usize, index: usize) -> DeviceSpec {
        DeviceSpec::new("worker", task, "cpu", index)
    }

    /// Parse a full device name. All four components required.
    pub fn parse(s: &str) -> Result<DeviceSpec> {
        let p = PartialDeviceSpec::parse(s)?;
        match (p.job, p.task, p.device_type, p.index) {
            (Some(job), Some(task), Some(device_type), Some(index)) => {
                Ok(DeviceSpec { job, task, device_type, index })
            }
            _ => Err(Status::invalid_argument(format!("device name {s:?} is not fully specified"))),
        }
    }
}

impl std::fmt::Display for DeviceSpec {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "/job:{}/task:{}/device:{}:{}", self.job, self.task, self.device_type, self.index)
    }
}

/// A partial device constraint: any subset of the components.
#[derive(Debug, Clone, PartialEq, Eq, Default)]
pub struct PartialDeviceSpec {
    pub job: Option<String>,
    pub task: Option<usize>,
    pub device_type: Option<String>,
    pub index: Option<usize>,
}

impl PartialDeviceSpec {
    pub fn any() -> PartialDeviceSpec {
        PartialDeviceSpec::default()
    }

    pub fn is_empty(&self) -> bool {
        self.job.is_none() && self.task.is_none() && self.device_type.is_none() && self.index.is_none()
    }

    /// Parse specs like "/job:worker/task:17", "/device:gpu:3",
    /// "/job:ps/device:cpu:0", "" (matches anything).
    pub fn parse(s: &str) -> Result<PartialDeviceSpec> {
        let mut out = PartialDeviceSpec::default();
        if s.is_empty() {
            return Ok(out);
        }
        let bad = || Status::invalid_argument(format!("malformed device spec {s:?}"));
        for part in s.split('/') {
            if part.is_empty() {
                continue;
            }
            let mut it = part.splitn(2, ':');
            let key = it.next().ok_or_else(bad)?;
            let value = it.next().ok_or_else(bad)?;
            match key {
                "job" => out.job = Some(value.to_string()),
                "task" | "replica" => {
                    out.task = Some(value.parse().map_err(|_| bad())?);
                }
                "device" => {
                    // "device:cpu:0" or "device:cpu" or "device:cpu:*"
                    let mut dv = value.splitn(2, ':');
                    let ty = dv.next().ok_or_else(bad)?;
                    out.device_type = Some(ty.to_lowercase());
                    if let Some(idx) = dv.next() {
                        if idx != "*" {
                            out.index = Some(idx.parse().map_err(|_| bad())?);
                        }
                    }
                }
                // Legacy bare "cpu:0" / "gpu:1" form.
                "cpu" | "gpu" | "tpu" => {
                    out.device_type = Some(key.to_string());
                    if value != "*" {
                        out.index = Some(value.parse().map_err(|_| bad())?);
                    }
                }
                _ => return Err(bad()),
            }
        }
        Ok(out)
    }

    pub fn matches(&self, spec: &DeviceSpec) -> bool {
        self.job.as_ref().map_or(true, |j| *j == spec.job)
            && self.task.map_or(true, |t| t == spec.task)
            && self.device_type.as_ref().map_or(true, |d| *d == spec.device_type)
            && self.index.map_or(true, |i| i == spec.index)
    }

    /// Merge two constraints; error when they conflict (used when a node's
    /// requested device meets its colocation group's constraint, §4.3).
    pub fn merge(&self, other: &PartialDeviceSpec) -> Result<PartialDeviceSpec> {
        fn combine<T: Clone + PartialEq + std::fmt::Debug>(
            a: &Option<T>,
            b: &Option<T>,
        ) -> Result<Option<T>> {
            match (a, b) {
                (Some(x), Some(y)) if x != y => Err(Status::invalid_argument(format!(
                    "conflicting device constraints: {x:?} vs {y:?}"
                ))),
                (Some(x), _) => Ok(Some(x.clone())),
                (None, y) => Ok(y.clone()),
            }
        }
        Ok(PartialDeviceSpec {
            job: combine(&self.job, &other.job)?,
            task: combine(&self.task, &other.task)?,
            device_type: combine(&self.device_type, &other.device_type)?,
            index: combine(&self.index, &other.index)?,
        })
    }
}

impl std::fmt::Display for PartialDeviceSpec {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        if let Some(j) = &self.job {
            write!(f, "/job:{j}")?;
        }
        if let Some(t) = self.task {
            write!(f, "/task:{t}")?;
        }
        if let Some(d) = &self.device_type {
            write!(f, "/device:{d}")?;
            if let Some(i) = self.index {
                write!(f, ":{i}")?;
            }
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_full() {
        let s = DeviceSpec::parse("/job:worker/task:17/device:gpu:3").unwrap();
        assert_eq!(s, DeviceSpec::new("worker", 17, "gpu", 3));
        assert_eq!(s.to_string(), "/job:worker/task:17/device:gpu:3");
    }

    #[test]
    fn parse_paper_examples() {
        // Both example names from §3 "Devices".
        assert!(DeviceSpec::parse("/job:localhost/task:0/device:cpu:0").is_ok());
        assert!(DeviceSpec::parse("/job:worker/task:17/device:cpu:3").is_ok());
    }

    #[test]
    fn parse_partial() {
        let p = PartialDeviceSpec::parse("/job:worker/task:17").unwrap();
        assert_eq!(p.job.as_deref(), Some("worker"));
        assert_eq!(p.task, Some(17));
        assert!(p.device_type.is_none());
        let q = PartialDeviceSpec::parse("/device:gpu:*").unwrap();
        assert_eq!(q.device_type.as_deref(), Some("gpu"));
        assert!(q.index.is_none());
        assert!(PartialDeviceSpec::parse("").unwrap().is_empty());
    }

    #[test]
    fn parse_rejects_garbage() {
        assert!(PartialDeviceSpec::parse("/bogus:1").is_err());
        assert!(PartialDeviceSpec::parse("/task:x").is_err());
        assert!(DeviceSpec::parse("/job:worker").is_err()); // not full
    }

    #[test]
    fn matching() {
        let d = DeviceSpec::new("worker", 2, "cpu", 1);
        assert!(PartialDeviceSpec::parse("/job:worker").unwrap().matches(&d));
        assert!(PartialDeviceSpec::parse("/device:cpu:1").unwrap().matches(&d));
        assert!(!PartialDeviceSpec::parse("/device:cpu:0").unwrap().matches(&d));
        assert!(!PartialDeviceSpec::parse("/job:ps").unwrap().matches(&d));
        assert!(PartialDeviceSpec::any().matches(&d));
    }

    #[test]
    fn merge_constraints() {
        let a = PartialDeviceSpec::parse("/job:worker").unwrap();
        let b = PartialDeviceSpec::parse("/device:cpu:0").unwrap();
        let m = a.merge(&b).unwrap();
        assert!(m.matches(&DeviceSpec::new("worker", 5, "cpu", 0)));
        let c = PartialDeviceSpec::parse("/job:ps").unwrap();
        assert!(a.merge(&c).is_err());
    }
}
