//! The intra-op compute pool: data parallelism *inside* one kernel.
//!
//! Each [`crate::device::Device`] owns two pools with distinct jobs, the
//! split the OSDI'16 follow-up paper exposes as the inter-op/intra-op
//! runtime knob: the inter-op [`crate::util::threadpool::ThreadPool`]
//! dispatches *ready nodes* (§3.1), while this pool splits *one kernel's
//! element loop* across cores — the role Eigen's internal threading plays
//! for real TensorFlow's CPU kernels.
//!
//! Design points:
//!
//! * **Deterministic contiguous chunks.** [`ComputePool::parallel_for`]
//!   splits `0..total` into contiguous ranges *at submit time* — chunk
//!   `i` always covers `[i*chunk, min(total, (i+1)*chunk))` — and every
//!   output element is produced by exactly one chunk with a fixed
//!   per-element operation order. Which thread runs a chunk is scheduler
//!   noise; the chunk→output mapping is not. Kernel results are
//!   bit-identical for every thread count (the determinism contract the
//!   parallel kernels and `tests/parallel.rs` rely on).
//! * **Per-worker deques with work stealing.** Each worker owns a chunk
//!   deque; submitters deal a job's pre-split chunks round-robin across
//!   the lanes (keeping one share for themselves). A worker drains its
//!   own deque front-to-back, then steals the *back half* of a victim's
//!   deque, victims visited in an order randomized from a fixed
//!   per-worker seed. Many concurrent steps (the serving fan-in case)
//!   and nested `parallel_for`s stop contending on one injector lock.
//! * **Small work runs inline.** When `total × cost_per_item` is under
//!   [`INLINE_WORK`] the caller's closure runs on the calling thread —
//!   small tensors never pay queueing or wakeup latency.
//! * **Lazy workers.** A pool of capacity `t` spawns its `t - 1` worker
//!   threads on first above-threshold job, so sessions that never run a
//!   large kernel cost nothing. The submitting thread always works too:
//!   it runs its dealt share, then claws back whatever of *its own job*
//!   is still queued before blocking.
//! * **Panics propagate.** A panic in any chunk — including one running
//!   on a thief — is caught, carried back, and re-raised on the
//!   submitting thread after every chunk has finished; the executor
//!   converts it into a `Status` instead of hanging the step.
//! * **Pooled kernel scratch.** [`ComputePool::take_scratch_f32`] /
//!   [`ComputePool::give_scratch_f32`] recycle packing buffers for
//!   kernel entry points that run outside a planned step (the
//!   `matmul_with_pool` free functions); in-step kernels use the
//!   `StepArena` scratch checkout instead.

use std::any::Any;
use std::cell::Cell;
use std::collections::VecDeque;
use std::ops::Range;
use std::panic::{catch_unwind, resume_unwind, AssertUnwindSafe};
use std::sync::atomic::{AtomicBool, AtomicUsize, Ordering};
use std::sync::{Arc, Condvar, Mutex};
use std::thread::JoinHandle;

use crate::util::rng::Pcg32;

/// Work (in `total × cost_per_item` units, roughly scalar flops) below
/// which `parallel_for` runs inline on the calling thread.
pub const INLINE_WORK: usize = 32 * 1024;

/// Target chunk count per configured thread (over-decomposition for load
/// balance when chunks are cheap enough to split this far).
const CHUNKS_PER_THREAD: usize = 4;

/// Minimum work per chunk, so synchronization stays amortized even when
/// `total` is huge and per-item cost tiny.
const MIN_CHUNK_WORK: usize = 16 * 1024;

/// Scratch vectors retained per pool for out-of-step packing buffers.
const MAX_POOLED_SCRATCH: usize = 8;

thread_local! {
    /// Set inside intra-op workers: nested `parallel_for` calls run
    /// inline instead of re-entering the deques (no deadlock, no
    /// oversubscription).
    static IN_INTRA_WORKER: Cell<bool> = const { Cell::new(false) };
}

fn in_intra_worker() -> bool {
    IN_INTRA_WORKER.with(|c| c.get())
}

/// One submitted `parallel_for`: a lifetime-erased chunk closure plus the
/// completion state every participating thread shares.
struct Job {
    /// The caller's closure, as a raw pointer so `Task`s queued in worker
    /// deques may hold it without a lifetime (a dangling *reference*
    /// there would be a validity violation, a dangling raw pointer is
    /// not). Every `Task` is removed from a deque exactly once, to run;
    /// `pending` counts un-run tasks and the submitting frame blocks
    /// until it reaches 0 — so every dereference happens while the
    /// closure is alive.
    task: *const (dyn Fn(Range<usize>) + Sync),
    /// Chunks not yet finished; 0 ⇒ the job is complete.
    pending: AtomicUsize,
    /// First panic payload from any chunk, re-raised on the submitter.
    panic: Mutex<Option<Box<dyn Any + Send>>>,
    done_mutex: Mutex<()>,
    done_cond: Condvar,
}

// Safety: `task` is only dereferenced under the one-run-per-task
// discipline documented on the field; every other field is already
// Send + Sync.
unsafe impl Send for Job {}
unsafe impl Sync for Job {}

/// One pre-split chunk of a job. The range was fixed at submit time, so
/// output bytes do not depend on which thread ends up running it.
struct Task {
    job: Arc<Job>,
    range: Range<usize>,
}

struct WorkerSlot {
    deque: Mutex<VecDeque<Task>>,
}

struct Inner {
    /// One slot per worker thread (`threads - 1` of them).
    slots: Vec<WorkerSlot>,
    /// Push epoch: bumped (under this lock) after every chunk deal, so a
    /// worker that re-checks the deques while holding the lock and then
    /// waits can never miss a wakeup — the pusher's bump + notify happen
    /// entirely after the worker's check or entirely before it.
    signal: Mutex<u64>,
    cond: Condvar,
    shutdown: AtomicBool,
}

/// A device's intra-op pool. `threads` counts the calling thread, so a
/// pool of 1 is fully serial (and [`ComputePool::serial`] builds that
/// without any queue state at all).
pub struct ComputePool {
    threads: usize,
    inner: Option<Arc<Inner>>,
    workers: Mutex<Vec<JoinHandle<()>>>,
    scratch: Mutex<Vec<Vec<f32>>>,
    name: String,
}

impl ComputePool {
    /// A pool of `threads` total lanes named `name` (worker threads are
    /// `{name}-{i}`). Workers spawn lazily on first parallel job.
    pub fn new(threads: usize, name: &str) -> ComputePool {
        let threads = threads.max(1);
        let inner = (threads > 1).then(|| {
            Arc::new(Inner {
                slots: (0..threads - 1)
                    .map(|_| WorkerSlot { deque: Mutex::new(VecDeque::new()) })
                    .collect(),
                signal: Mutex::new(0),
                cond: Condvar::new(),
                shutdown: AtomicBool::new(false),
            })
        });
        ComputePool {
            threads,
            inner,
            workers: Mutex::new(Vec::new()),
            scratch: Mutex::new(Vec::new()),
            name: name.to_string(),
        }
    }

    /// A zero-state serial pool: every `parallel_for` runs inline. Free
    /// kernel functions use this so they need no device.
    pub fn serial() -> ComputePool {
        ComputePool {
            threads: 1,
            inner: None,
            workers: Mutex::new(Vec::new()),
            scratch: Mutex::new(Vec::new()),
            name: String::new(),
        }
    }

    /// Configured parallelism (including the calling thread).
    pub fn threads(&self) -> usize {
        self.threads
    }

    /// Would a `parallel_for(total, cost_per_item, …)` issued right now
    /// fan out to workers (true) or run inline on the calling thread
    /// (false)? Lets kernels pick a cheaper serial fill strategy (e.g.
    /// push-fill instead of zero-fill-then-overwrite) when no chunking
    /// will happen.
    pub fn would_parallelize(&self, total: usize, cost_per_item: usize) -> bool {
        self.inner.is_some()
            && total > 1
            && total.saturating_mul(cost_per_item.max(1)) >= INLINE_WORK
            && !in_intra_worker()
    }

    /// Check out a scratch `Vec<f32>` with capacity for at least `n`
    /// elements (length 0). Return it with
    /// [`ComputePool::give_scratch_f32`] so the next out-of-step matmul
    /// reuses the allocation instead of paying the allocator.
    pub fn take_scratch_f32(&self, n: usize) -> Vec<f32> {
        let mut pool = match self.scratch.lock() {
            Ok(g) => g,
            Err(p) => p.into_inner(),
        };
        if let Some(pos) = pool.iter().position(|v| v.capacity() >= n) {
            let mut v = pool.swap_remove(pos);
            v.clear();
            return v;
        }
        drop(pool);
        Vec::with_capacity(n)
    }

    /// Return a scratch vector checked out with
    /// [`ComputePool::take_scratch_f32`]. Keeps at most
    /// [`MAX_POOLED_SCRATCH`] vectors; extras are freed.
    pub fn give_scratch_f32(&self, v: Vec<f32>) {
        if v.capacity() == 0 {
            return;
        }
        let mut pool = match self.scratch.lock() {
            Ok(g) => g,
            Err(p) => p.into_inner(),
        };
        if pool.len() < MAX_POOLED_SCRATCH {
            pool.push(v);
        }
    }

    /// Run `f` over every index in `0..total`, split into deterministic
    /// contiguous chunks executed across the pool (the calling thread
    /// included). `cost_per_item` is the approximate scalar-op cost of
    /// one item and drives both the inline threshold and the chunk
    /// grain. Blocks until every chunk has finished; a panic in any
    /// chunk is re-raised here once the rest have completed.
    pub fn parallel_for<F>(&self, total: usize, cost_per_item: usize, f: F)
    where
        F: Fn(Range<usize>) + Sync,
    {
        let inner = match &self.inner {
            Some(inner) if self.would_parallelize(total, cost_per_item) => inner,
            _ => {
                f(0..total);
                return;
            }
        };
        let min_chunk = (MIN_CHUNK_WORK / cost_per_item.max(1)).max(1);
        let chunk = total.div_ceil(self.threads * CHUNKS_PER_THREAD).max(min_chunk);
        let num_chunks = total.div_ceil(chunk);
        if num_chunks <= 1 {
            f(0..total);
            return;
        }
        self.ensure_workers(inner);

        // Erase the closure's lifetime into a raw pointer so queued tasks
        // can hold it (see the `Job::task` safety comment: dereferences
        // only happen before this frame returns, while `f` is alive).
        let task = &f as &(dyn Fn(Range<usize>) + Sync) as *const (dyn Fn(Range<usize>) + Sync);
        let job = Arc::new(Job {
            task,
            pending: AtomicUsize::new(num_chunks),
            panic: Mutex::new(None),
            done_mutex: Mutex::new(()),
            done_cond: Condvar::new(),
        });

        // Deal the pre-split chunks round-robin across all lanes: lane 0
        // is the submitter's local share, lanes 1.. map onto worker
        // deques. The range of chunk `i` is a pure function of
        // (i, chunk, total), so scheduling never touches output bytes.
        let lanes = self.threads;
        let mut local: Vec<Task> = Vec::new();
        let mut per_slot: Vec<VecDeque<Task>> =
            (0..inner.slots.len()).map(|_| VecDeque::new()).collect();
        for i in 0..num_chunks {
            let start = i * chunk;
            let t = Task { job: Arc::clone(&job), range: start..total.min(start + chunk) };
            match i % lanes {
                0 => local.push(t),
                lane => per_slot[lane - 1].push_back(t),
            }
        }
        for (s, mut batch) in per_slot.into_iter().enumerate() {
            if batch.is_empty() {
                continue;
            }
            let mut q = match inner.slots[s].deque.lock() {
                Ok(g) => g,
                Err(p) => p.into_inner(),
            };
            q.append(&mut batch);
        }
        {
            // Bump the push epoch *after* the deques are filled so any
            // worker that observed them empty is now either awake or
            // about to be notified (it re-checks under this lock).
            let mut epoch = match inner.signal.lock() {
                Ok(g) => g,
                Err(p) => p.into_inner(),
            };
            *epoch = epoch.wrapping_add(1);
        }
        inner.cond.notify_all();

        // The submitter works too: its dealt share first, then whatever
        // of *this job* is still sitting in worker deques (idle workers
        // race it for those; either way the job drains), then it waits
        // out chunks currently running elsewhere.
        for t in &local {
            run_task(t);
        }
        steal_own_job(inner, &job);
        {
            let mut g = job.done_mutex.lock().unwrap();
            while job.pending.load(Ordering::Acquire) != 0 {
                g = job.done_cond.wait(g).unwrap();
            }
        }
        if let Some(p) = job.panic.lock().unwrap().take() {
            resume_unwind(p);
        }
    }

    /// [`ComputePool::parallel_for`] over `total` items where each item
    /// owns `out.len() / total` consecutive elements of `out`: the
    /// closure receives the item range plus the matching disjoint
    /// `&mut` view (row panels of a matmul output, rows of a softmax,
    /// element chunks when the width is 1). `out.len()` must be a
    /// multiple of `total`.
    pub fn parallel_for_mut<T, F>(&self, total: usize, cost_per_item: usize, out: &mut [T], f: F)
    where
        T: Send,
        F: Fn(Range<usize>, &mut [T]) + Sync,
    {
        if total == 0 {
            return;
        }
        assert!(
            out.len() % total == 0,
            "parallel_for_mut: out.len() {} is not a multiple of total {total}",
            out.len(),
        );
        let width = out.len() / total;
        let ptr = SendPtr(out.as_mut_ptr());
        self.parallel_for(total, cost_per_item, move |r: Range<usize>| {
            // Safety: chunks are disjoint contiguous item ranges, each
            // item owns `width` consecutive elements, and the caller's
            // exclusive borrow of `out` spans the whole parallel_for
            // call (which blocks until every chunk completes).
            let view =
                unsafe { std::slice::from_raw_parts_mut(ptr.0.add(r.start * width), r.len() * width) };
            f(r, view);
        });
    }

    /// Two-output [`ComputePool::parallel_for_mut`]: each item owns
    /// `out1.len() / total` elements of `out1` *and* `out2.len() / total`
    /// elements of `out2` (MaxPool's value + argmax planes, the xent
    /// pair's loss + backprop). Both lengths must be multiples of
    /// `total`.
    pub fn parallel_for_mut2<T1, T2, F>(
        &self,
        total: usize,
        cost_per_item: usize,
        out1: &mut [T1],
        out2: &mut [T2],
        f: F,
    ) where
        T1: Send,
        T2: Send,
        F: Fn(Range<usize>, &mut [T1], &mut [T2]) + Sync,
    {
        if total == 0 {
            return;
        }
        assert!(
            out1.len() % total == 0 && out2.len() % total == 0,
            "parallel_for_mut2: lengths {} / {} are not multiples of total {total}",
            out1.len(),
            out2.len(),
        );
        let w1 = out1.len() / total;
        let w2 = out2.len() / total;
        let p1 = SendPtr(out1.as_mut_ptr());
        let p2 = SendPtr(out2.as_mut_ptr());
        self.parallel_for(total, cost_per_item, move |r: Range<usize>| {
            // Safety: as in `parallel_for_mut`, per output slice.
            let v1 =
                unsafe { std::slice::from_raw_parts_mut(p1.0.add(r.start * w1), r.len() * w1) };
            let v2 =
                unsafe { std::slice::from_raw_parts_mut(p2.0.add(r.start * w2), r.len() * w2) };
            f(r, v1, v2);
        });
    }

    /// Spawn any not-yet-started workers (capacity minus the caller).
    fn ensure_workers(&self, inner: &Arc<Inner>) {
        let mut ws = match self.workers.lock() {
            Ok(g) => g,
            Err(p) => p.into_inner(),
        };
        while ws.len() + 1 < self.threads {
            let inner = Arc::clone(inner);
            let me = ws.len();
            let handle = std::thread::Builder::new()
                .name(format!("{}-{}", self.name, me))
                .spawn(move || worker_loop(inner, me))
                .expect("spawn intra-op worker");
            ws.push(handle);
        }
    }
}

impl std::fmt::Debug for ComputePool {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "ComputePool(threads={})", self.threads)
    }
}

impl Drop for ComputePool {
    fn drop(&mut self) {
        if let Some(inner) = &self.inner {
            inner.shutdown.store(true, Ordering::SeqCst);
            inner.cond.notify_all();
            let me = std::thread::current().id();
            let ws = match self.workers.get_mut() {
                Ok(v) => std::mem::take(v),
                Err(p) => std::mem::take(p.into_inner()),
            };
            for w in ws {
                // Never join yourself (a device dropped from its own
                // worker); the shutdown flag lets the thread exit alone.
                if w.thread().id() == me {
                    continue;
                }
                let _ = w.join();
            }
        }
    }
}

/// Run one task's chunk, catching panics into the job and signalling
/// completion when the last chunk finishes.
fn run_task(t: &Task) {
    // Safety: this task was dequeued exactly once and `pending` has not
    // yet been decremented for it, so the submitting frame — and the
    // closure — are still alive.
    let task = unsafe { &*t.job.task };
    let range = t.range.clone();
    if let Err(p) = catch_unwind(AssertUnwindSafe(|| task(range))) {
        let mut slot = t.job.panic.lock().unwrap();
        if slot.is_none() {
            *slot = Some(p);
        }
    }
    if t.job.pending.fetch_sub(1, Ordering::AcqRel) == 1 {
        let _g = t.job.done_mutex.lock().unwrap();
        t.job.done_cond.notify_all();
    }
}

/// Submitter-side clawback: pull every still-queued task of `job` out of
/// the worker deques and run it here. Sweeps repeat until a full pass
/// finds nothing, since a thief may move our tasks between slots
/// mid-sweep; tasks never re-enter a deque after being taken, so this
/// terminates.
fn steal_own_job(inner: &Inner, job: &Arc<Job>) {
    loop {
        let mut ran = false;
        for slot in &inner.slots {
            let mut taken: Vec<Task> = Vec::new();
            {
                let mut q = match slot.deque.lock() {
                    Ok(g) => g,
                    Err(p) => p.into_inner(),
                };
                let mut i = 0;
                while i < q.len() {
                    if Arc::ptr_eq(&q[i].job, job) {
                        if let Some(t) = q.remove(i) {
                            taken.push(t);
                        }
                    } else {
                        i += 1;
                    }
                }
            }
            for t in &taken {
                run_task(t);
                ran = true;
            }
        }
        if !ran {
            return;
        }
    }
}

fn pop_own(inner: &Inner, me: usize) -> Option<Task> {
    let mut q = match inner.slots[me].deque.lock() {
        Ok(g) => g,
        Err(p) => p.into_inner(),
    };
    q.pop_front()
}

/// Steal the back half of some victim's deque: run the first stolen task
/// now, queue the rest locally. Victims are visited in an order drawn
/// from the worker's own seeded RNG so concurrent thieves fan out over
/// different victims instead of convoying. Returns whether anything ran.
fn steal_some(inner: &Inner, me: usize, rng: &mut Pcg32) -> bool {
    let n = inner.slots.len();
    if n <= 1 {
        return false;
    }
    let start = rng.next_below(n as u32) as usize;
    for k in 0..n {
        let victim = (start + k) % n;
        if victim == me {
            continue;
        }
        let mut stolen = {
            let mut q = match inner.slots[victim].deque.lock() {
                Ok(g) => g,
                Err(p) => p.into_inner(),
            };
            let len = q.len();
            if len == 0 {
                continue;
            }
            q.split_off(len - len.div_ceil(2))
        };
        let first = stolen.pop_front().expect("stole at least one task");
        if !stolen.is_empty() {
            let mut q = match inner.slots[me].deque.lock() {
                Ok(g) => g,
                Err(p) => p.into_inner(),
            };
            q.append(&mut stolen);
        }
        run_task(&first);
        return true;
    }
    false
}

fn worker_loop(inner: Arc<Inner>, me: usize) {
    IN_INTRA_WORKER.with(|c| c.set(true));
    // Fixed per-worker seed: victim order is reproducible noise, and
    // output bytes never depend on it (chunk ranges are fixed at submit).
    let mut rng = Pcg32::with_stream(0x5EED ^ me as u64, me as u64);
    loop {
        while let Some(t) = pop_own(&inner, me) {
            run_task(&t);
        }
        if steal_some(&inner, me, &mut rng) {
            continue;
        }
        // Park. Re-check every deque while holding the signal lock:
        // pushers fill deques first and bump the epoch under this lock
        // after, so either we see their tasks here, or their
        // bump + notify happens after we wait — never a lost wakeup.
        // (Pushers never hold a deque lock while taking the signal lock,
        // so taking deque locks under it cannot deadlock.)
        let g = match inner.signal.lock() {
            Ok(g) => g,
            Err(p) => p.into_inner(),
        };
        if inner.shutdown.load(Ordering::SeqCst) {
            return;
        }
        let any_work = inner.slots.iter().any(|s| {
            let q = match s.deque.lock() {
                Ok(g) => g,
                Err(p) => p.into_inner(),
            };
            !q.is_empty()
        });
        if any_work {
            continue;
        }
        let _g = inner.cond.wait(g).unwrap();
    }
}

/// Raw-pointer wrapper that lets disjoint chunk views cross threads.
#[derive(Clone, Copy)]
struct SendPtr<T>(*mut T);
// Safety: only ever dereferenced through disjoint ranges while the
// caller's exclusive borrow is alive (see `parallel_for_mut`).
unsafe impl<T: Send> Send for SendPtr<T> {}
unsafe impl<T: Send> Sync for SendPtr<T> {}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::AtomicU64;

    #[test]
    fn covers_every_index_exactly_once() {
        let pool = ComputePool::new(4, "test-cover");
        let n = 100_000;
        let hits: Vec<AtomicU64> = (0..n).map(|_| AtomicU64::new(0)).collect();
        pool.parallel_for(n, 64, |r| {
            for i in r {
                hits[i].fetch_add(1, Ordering::Relaxed);
            }
        });
        assert!(hits.iter().all(|h| h.load(Ordering::Relaxed) == 1));
    }

    #[test]
    fn small_work_runs_inline_on_caller() {
        let pool = ComputePool::new(4, "test-inline");
        let caller = std::thread::current().id();
        let same_thread = std::sync::Mutex::new(true);
        pool.parallel_for(8, 1, |_r| {
            if std::thread::current().id() != caller {
                *same_thread.lock().unwrap() = false;
            }
        });
        assert!(*same_thread.lock().unwrap());
        // And no workers were ever spawned for it.
        assert!(pool.workers.lock().unwrap().is_empty());
    }

    #[test]
    fn parallel_for_mut_views_are_disjoint_and_complete() {
        let pool = ComputePool::new(4, "test-mut");
        let total = 10_000;
        let width = 7;
        let mut out = vec![0u64; total * width];
        pool.parallel_for_mut(total, 64, &mut out, |r, view| {
            assert_eq!(view.len(), r.len() * width);
            for (j, v) in view.iter_mut().enumerate() {
                *v = (r.start * width + j) as u64;
            }
        });
        for (i, &v) in out.iter().enumerate() {
            assert_eq!(v, i as u64);
        }
    }

    #[test]
    fn parallel_for_mut2_views_are_disjoint_and_complete() {
        let pool = ComputePool::new(4, "test-mut2");
        let total = 6_000;
        let (w1, w2) = (5, 3);
        let mut a = vec![0u64; total * w1];
        let mut b = vec![0u64; total * w2];
        pool.parallel_for_mut2(total, 64, &mut a, &mut b, |r, va, vb| {
            assert_eq!(va.len(), r.len() * w1);
            assert_eq!(vb.len(), r.len() * w2);
            for (j, v) in va.iter_mut().enumerate() {
                *v = (r.start * w1 + j) as u64;
            }
            for (j, v) in vb.iter_mut().enumerate() {
                *v = 1_000_000 + (r.start * w2 + j) as u64;
            }
        });
        for (i, &v) in a.iter().enumerate() {
            assert_eq!(v, i as u64);
        }
        for (i, &v) in b.iter().enumerate() {
            assert_eq!(v, 1_000_000 + i as u64);
        }
    }

    #[test]
    fn chunking_independent_of_results() {
        // Same deterministic function under 1, 2, 8 threads → same bytes.
        let compute = |threads: usize| -> Vec<f32> {
            let pool = ComputePool::new(threads, "test-det");
            let n = 65_536;
            let mut out = vec![0f32; n];
            pool.parallel_for_mut(n, 8, &mut out, |r, view| {
                for (j, v) in view.iter_mut().enumerate() {
                    let i = r.start + j;
                    *v = ((i as f32) * 0.001).sin();
                }
            });
            out
        };
        let a = compute(1);
        assert_eq!(a, compute(2));
        assert_eq!(a, compute(8));
    }

    #[test]
    fn worker_panic_propagates_to_submitter() {
        let pool = ComputePool::new(4, "test-panic");
        let r = catch_unwind(AssertUnwindSafe(|| {
            pool.parallel_for(1 << 16, 64, |_r| panic!("chunk boom"));
        }));
        let p = r.expect_err("panic must propagate");
        let msg = p.downcast_ref::<&str>().copied().unwrap_or("");
        assert_eq!(msg, "chunk boom");
        // The pool stays usable afterwards.
        let sum = AtomicU64::new(0);
        pool.parallel_for(1 << 16, 64, |r| {
            sum.fetch_add(r.len() as u64, Ordering::Relaxed);
        });
        assert_eq!(sum.load(Ordering::Relaxed), 1 << 16);
    }

    #[test]
    fn panic_in_one_job_leaves_concurrent_jobs_intact() {
        // A panicking job and healthy jobs share the deques; only the
        // panicking submitter sees the payload (even when the chunk ran
        // on a thief).
        let pool = Arc::new(ComputePool::new(4, "test-panic-mix"));
        std::thread::scope(|s| {
            for t in 0..4 {
                let pool = Arc::clone(&pool);
                s.spawn(move || {
                    for round in 0..8 {
                        if t == 0 {
                            let r = catch_unwind(AssertUnwindSafe(|| {
                                pool.parallel_for(1 << 16, 64, |_r| panic!("mix boom"));
                            }));
                            assert!(r.is_err(), "round {round}");
                        } else {
                            let sum = AtomicU64::new(0);
                            pool.parallel_for(1 << 16, 64, |r| {
                                sum.fetch_add(r.len() as u64, Ordering::Relaxed);
                            });
                            assert_eq!(sum.load(Ordering::Relaxed), 1 << 16, "round {round}");
                        }
                    }
                });
            }
        });
    }

    #[test]
    fn nested_parallel_for_runs_inline() {
        let pool = Arc::new(ComputePool::new(4, "test-nested"));
        let inner_pool = Arc::clone(&pool);
        let count = AtomicU64::new(0);
        pool.parallel_for(1 << 16, 64, |r| {
            // A nested call from a worker must not deadlock.
            inner_pool.parallel_for(4, 20_000, |rr| {
                count.fetch_add(rr.len() as u64, Ordering::Relaxed);
            });
            let _ = r;
        });
        assert!(count.load(Ordering::Relaxed) > 0);
    }

    #[test]
    fn serial_pool_is_inline_always() {
        let pool = ComputePool::serial();
        let caller = std::thread::current().id();
        let ok = std::sync::Mutex::new(true);
        pool.parallel_for(1 << 20, 64, |_r| {
            if std::thread::current().id() != caller {
                *ok.lock().unwrap() = false;
            }
        });
        assert!(*ok.lock().unwrap());
    }

    #[test]
    fn concurrent_jobs_from_many_threads() {
        let pool = Arc::new(ComputePool::new(4, "test-concurrent"));
        std::thread::scope(|s| {
            for t in 0..6 {
                let pool = Arc::clone(&pool);
                s.spawn(move || {
                    let sum = AtomicU64::new(0);
                    pool.parallel_for(40_000, 8, |r| {
                        sum.fetch_add(r.map(|i| i as u64).sum::<u64>(), Ordering::Relaxed);
                    });
                    let n = 40_000u64;
                    assert_eq!(sum.load(Ordering::Relaxed), n * (n - 1) / 2, "thread {t}");
                });
            }
        });
    }

    #[test]
    fn many_concurrent_small_steps_stress() {
        // The serving fan-in shape: many submitters, many small jobs,
        // all racing the same deques. Every job must see every index
        // exactly once regardless of who stole what.
        let pool = Arc::new(ComputePool::new(4, "test-stress"));
        std::thread::scope(|s| {
            for t in 0..8 {
                let pool = Arc::clone(&pool);
                s.spawn(move || {
                    for round in 0..50 {
                        let n = 40_000 + (t * 997 + round * 131) % 5_000;
                        let sum = AtomicU64::new(0);
                        pool.parallel_for(n, 8, |r| {
                            sum.fetch_add(r.map(|i| i as u64).sum::<u64>(), Ordering::Relaxed);
                        });
                        let n = n as u64;
                        assert_eq!(sum.load(Ordering::Relaxed), n * (n - 1) / 2);
                    }
                });
            }
        });
    }

    #[test]
    fn scratch_pool_recycles_capacity() {
        let pool = ComputePool::new(2, "test-scratch");
        let mut v = pool.take_scratch_f32(1024);
        assert!(v.capacity() >= 1024);
        assert!(v.is_empty());
        v.resize(1024, 1.0);
        let ptr = v.as_ptr();
        pool.give_scratch_f32(v);
        let v2 = pool.take_scratch_f32(512);
        assert!(v2.is_empty());
        assert!(v2.capacity() >= 512);
        assert_eq!(v2.as_ptr(), ptr, "smaller request reuses the pooled buffer");
        pool.give_scratch_f32(v2);
    }
}
