//! The intra-op compute pool: data parallelism *inside* one kernel.
//!
//! Each [`crate::device::Device`] owns two pools with distinct jobs, the
//! split the OSDI'16 follow-up paper exposes as the inter-op/intra-op
//! runtime knob: the inter-op [`crate::util::threadpool::ThreadPool`]
//! dispatches *ready nodes* (§3.1), while this pool splits *one kernel's
//! element loop* across cores — the role Eigen's internal threading plays
//! for real TensorFlow's CPU kernels.
//!
//! Design points:
//!
//! * **Deterministic contiguous chunks.** [`ComputePool::parallel_for`]
//!   splits `0..total` into contiguous ranges and every output element is
//!   produced by exactly one chunk with a fixed per-element operation
//!   order, so kernel results are bit-identical for every thread count
//!   (the determinism contract the parallel kernels and
//!   `tests/parallel.rs` rely on).
//! * **Small work runs inline.** When `total × cost_per_item` is under
//!   [`INLINE_WORK`] the caller's closure runs on the calling thread —
//!   small tensors never pay queueing or wakeup latency.
//! * **Lazy workers.** A pool of capacity `t` spawns its `t - 1` worker
//!   threads on first above-threshold job, so sessions that never run a
//!   large kernel cost nothing. The submitting thread always works too.
//! * **Panics propagate.** A panic in a worker chunk is caught, carried
//!   back, and re-raised on the submitting thread after every chunk has
//!   finished — the executor converts it into a `Status` instead of
//!   hanging the step (see `executor`'s kernel `catch_unwind`).

use std::any::Any;
use std::cell::Cell;
use std::collections::VecDeque;
use std::ops::Range;
use std::panic::{catch_unwind, resume_unwind, AssertUnwindSafe};
use std::sync::atomic::{AtomicBool, AtomicUsize, Ordering};
use std::sync::{Arc, Condvar, Mutex};
use std::thread::JoinHandle;

/// Work (in `total × cost_per_item` units, roughly scalar flops) below
/// which `parallel_for` runs inline on the calling thread.
pub const INLINE_WORK: usize = 32 * 1024;

/// Target chunk count per configured thread (over-decomposition for load
/// balance when chunks are cheap enough to split this far).
const CHUNKS_PER_THREAD: usize = 4;

/// Minimum work per chunk, so synchronization stays amortized even when
/// `total` is huge and per-item cost tiny.
const MIN_CHUNK_WORK: usize = 16 * 1024;

thread_local! {
    /// Set inside intra-op workers: nested `parallel_for` calls run
    /// inline instead of re-entering the queue (no deadlock, no
    /// oversubscription).
    static IN_INTRA_WORKER: Cell<bool> = const { Cell::new(false) };
}

fn in_intra_worker() -> bool {
    IN_INTRA_WORKER.with(|c| c.get())
}

/// One submitted `parallel_for`: a lifetime-erased chunk closure plus the
/// claim/completion state every participating thread shares.
struct Job {
    /// The caller's closure, as a raw pointer so the `Job` may harmlessly
    /// outlive the `parallel_for` frame (exhausted-job husks linger in
    /// the queue and in worker-held Arcs; a dangling *reference* there
    /// would be a validity violation, a dangling raw pointer is not).
    /// Dereferenced only inside the claim window — chunk index <
    /// `num_chunks` — and the submitting frame blocks until
    /// `pending == 0`, so every dereference happens while the closure is
    /// alive.
    task: *const (dyn Fn(Range<usize>) + Sync),
    total: usize,
    chunk: usize,
    num_chunks: usize,
    /// Next unclaimed chunk index (may run past `num_chunks`; claims
    /// beyond the end are no-ops).
    next: AtomicUsize,
    /// Chunks not yet finished; 0 ⇒ the job is complete.
    pending: AtomicUsize,
    /// First panic payload from any chunk, re-raised on the submitter.
    panic: Mutex<Option<Box<dyn Any + Send>>>,
    done_mutex: Mutex<()>,
    done_cond: Condvar,
}

// Safety: `task` is only dereferenced under the claim-window discipline
// documented on the field; every other field is already Send + Sync.
unsafe impl Send for Job {}
unsafe impl Sync for Job {}

struct Inner {
    queue: Mutex<VecDeque<Arc<Job>>>,
    cond: Condvar,
    shutdown: AtomicBool,
}

/// A device's intra-op pool. `threads` counts the calling thread, so a
/// pool of 1 is fully serial (and [`ComputePool::serial`] builds that
/// without any queue state at all).
pub struct ComputePool {
    threads: usize,
    inner: Option<Arc<Inner>>,
    workers: Mutex<Vec<JoinHandle<()>>>,
    name: String,
}

impl ComputePool {
    /// A pool of `threads` total lanes named `name` (worker threads are
    /// `{name}-{i}`). Workers spawn lazily on first parallel job.
    pub fn new(threads: usize, name: &str) -> ComputePool {
        let threads = threads.max(1);
        let inner = (threads > 1).then(|| {
            Arc::new(Inner {
                queue: Mutex::new(VecDeque::new()),
                cond: Condvar::new(),
                shutdown: AtomicBool::new(false),
            })
        });
        ComputePool { threads, inner, workers: Mutex::new(Vec::new()), name: name.to_string() }
    }

    /// A zero-state serial pool: every `parallel_for` runs inline. Free
    /// kernel functions use this so they need no device.
    pub fn serial() -> ComputePool {
        ComputePool { threads: 1, inner: None, workers: Mutex::new(Vec::new()), name: String::new() }
    }

    /// Configured parallelism (including the calling thread).
    pub fn threads(&self) -> usize {
        self.threads
    }

    /// Would a `parallel_for(total, cost_per_item, …)` issued right now
    /// fan out to workers (true) or run inline on the calling thread
    /// (false)? Lets kernels pick a cheaper serial fill strategy (e.g.
    /// push-fill instead of zero-fill-then-overwrite) when no chunking
    /// will happen.
    pub fn would_parallelize(&self, total: usize, cost_per_item: usize) -> bool {
        self.inner.is_some()
            && total > 1
            && total.saturating_mul(cost_per_item.max(1)) >= INLINE_WORK
            && !in_intra_worker()
    }

    /// Run `f` over every index in `0..total`, split into deterministic
    /// contiguous chunks executed across the pool (the calling thread
    /// included). `cost_per_item` is the approximate scalar-op cost of
    /// one item and drives both the inline threshold and the chunk
    /// grain. Blocks until every chunk has finished; a panic in any
    /// chunk is re-raised here once the rest have completed.
    pub fn parallel_for<F>(&self, total: usize, cost_per_item: usize, f: F)
    where
        F: Fn(Range<usize>) + Sync,
    {
        let inner = match &self.inner {
            Some(inner) if self.would_parallelize(total, cost_per_item) => inner,
            _ => {
                f(0..total);
                return;
            }
        };
        let min_chunk = (MIN_CHUNK_WORK / cost_per_item.max(1)).max(1);
        let chunk = total.div_ceil(self.threads * CHUNKS_PER_THREAD).max(min_chunk);
        let num_chunks = total.div_ceil(chunk);
        if num_chunks <= 1 {
            f(0..total);
            return;
        }
        self.ensure_workers(inner);

        // Erase the closure's lifetime into a raw pointer so workers can
        // hold it (see the `Job::task` safety comment: dereferences only
        // happen before this frame returns, while `f` is alive).
        let task: *const (dyn Fn(Range<usize>) + Sync) = unsafe {
            std::mem::transmute::<
                &(dyn Fn(Range<usize>) + Sync),
                *const (dyn Fn(Range<usize>) + Sync),
            >(&f)
        };
        let job = Arc::new(Job {
            task,
            total,
            chunk,
            num_chunks,
            next: AtomicUsize::new(0),
            pending: AtomicUsize::new(num_chunks),
            panic: Mutex::new(None),
            done_mutex: Mutex::new(()),
            done_cond: Condvar::new(),
        });
        {
            let mut q = inner.queue.lock().unwrap();
            q.push_back(Arc::clone(&job));
        }
        inner.cond.notify_all();

        // The submitter claims chunks like any worker, then waits out the
        // stragglers.
        run_chunks(&job);
        {
            let mut g = job.done_mutex.lock().unwrap();
            while job.pending.load(Ordering::Acquire) != 0 {
                g = job.done_cond.wait(g).unwrap();
            }
        }
        if let Some(p) = job.panic.lock().unwrap().take() {
            resume_unwind(p);
        }
    }

    /// [`ComputePool::parallel_for`] over `total` items where each item
    /// owns `out.len() / total` consecutive elements of `out`: the
    /// closure receives the item range plus the matching disjoint
    /// `&mut` view (row panels of a matmul output, rows of a softmax,
    /// element chunks when the width is 1). `out.len()` must be a
    /// multiple of `total`.
    pub fn parallel_for_mut<T, F>(&self, total: usize, cost_per_item: usize, out: &mut [T], f: F)
    where
        T: Send,
        F: Fn(Range<usize>, &mut [T]) + Sync,
    {
        if total == 0 {
            return;
        }
        assert!(
            out.len() % total == 0,
            "parallel_for_mut: out.len() {} is not a multiple of total {total}",
            out.len(),
        );
        let width = out.len() / total;
        let ptr = SendPtr(out.as_mut_ptr());
        self.parallel_for(total, cost_per_item, move |r: Range<usize>| {
            // Safety: chunks are disjoint contiguous item ranges, each
            // item owns `width` consecutive elements, and the caller's
            // exclusive borrow of `out` spans the whole parallel_for
            // call (which blocks until every chunk completes).
            let view =
                unsafe { std::slice::from_raw_parts_mut(ptr.0.add(r.start * width), r.len() * width) };
            f(r, view);
        });
    }

    /// Spawn any not-yet-started workers (capacity minus the caller).
    fn ensure_workers(&self, inner: &Arc<Inner>) {
        let mut ws = match self.workers.lock() {
            Ok(g) => g,
            Err(p) => p.into_inner(),
        };
        while ws.len() + 1 < self.threads {
            let inner = Arc::clone(inner);
            let handle = std::thread::Builder::new()
                .name(format!("{}-{}", self.name, ws.len()))
                .spawn(move || worker_loop(inner))
                .expect("spawn intra-op worker");
            ws.push(handle);
        }
    }
}

impl std::fmt::Debug for ComputePool {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "ComputePool(threads={})", self.threads)
    }
}

impl Drop for ComputePool {
    fn drop(&mut self) {
        if let Some(inner) = &self.inner {
            inner.shutdown.store(true, Ordering::SeqCst);
            inner.cond.notify_all();
            let me = std::thread::current().id();
            let ws = match self.workers.get_mut() {
                Ok(v) => std::mem::take(v),
                Err(p) => std::mem::take(p.into_inner()),
            };
            for w in ws {
                // Never join yourself (a device dropped from its own
                // worker); the shutdown flag lets the thread exit alone.
                if w.thread().id() == me {
                    continue;
                }
                let _ = w.join();
            }
        }
    }
}

/// Claim and run chunks of `job` until none remain.
fn run_chunks(job: &Job) {
    loop {
        let i = job.next.fetch_add(1, Ordering::Relaxed);
        if i >= job.num_chunks {
            return;
        }
        let start = i * job.chunk;
        let end = job.total.min(start + job.chunk);
        // Safety: we hold a claimed chunk (i < num_chunks), so the
        // submitting frame — and the closure — are still alive (it blocks
        // until this chunk's `pending` decrement below).
        let task = unsafe { &*job.task };
        if let Err(p) = catch_unwind(AssertUnwindSafe(|| task(start..end))) {
            let mut slot = job.panic.lock().unwrap();
            if slot.is_none() {
                *slot = Some(p);
            }
        }
        if job.pending.fetch_sub(1, Ordering::AcqRel) == 1 {
            let _g = job.done_mutex.lock().unwrap();
            job.done_cond.notify_all();
        }
    }
}

fn worker_loop(inner: Arc<Inner>) {
    IN_INTRA_WORKER.with(|c| c.set(true));
    loop {
        let job = {
            let mut q = inner.queue.lock().unwrap();
            loop {
                // Exhausted jobs at the front are husks: every chunk is
                // claimed (maybe still running elsewhere) — drop them.
                while q
                    .front()
                    .is_some_and(|j| j.next.load(Ordering::Relaxed) >= j.num_chunks)
                {
                    q.pop_front();
                }
                if let Some(j) = q.front() {
                    break Arc::clone(j);
                }
                if inner.shutdown.load(Ordering::SeqCst) {
                    return;
                }
                q = inner.cond.wait(q).unwrap();
            }
        };
        run_chunks(&job);
    }
}

/// Raw-pointer wrapper that lets disjoint chunk views cross threads.
#[derive(Clone, Copy)]
struct SendPtr<T>(*mut T);
// Safety: only ever dereferenced through disjoint ranges while the
// caller's exclusive borrow is alive (see `parallel_for_mut`).
unsafe impl<T: Send> Send for SendPtr<T> {}
unsafe impl<T: Send> Sync for SendPtr<T> {}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::AtomicU64;

    #[test]
    fn covers_every_index_exactly_once() {
        let pool = ComputePool::new(4, "test-cover");
        let n = 100_000;
        let hits: Vec<AtomicU64> = (0..n).map(|_| AtomicU64::new(0)).collect();
        pool.parallel_for(n, 64, |r| {
            for i in r {
                hits[i].fetch_add(1, Ordering::Relaxed);
            }
        });
        assert!(hits.iter().all(|h| h.load(Ordering::Relaxed) == 1));
    }

    #[test]
    fn small_work_runs_inline_on_caller() {
        let pool = ComputePool::new(4, "test-inline");
        let caller = std::thread::current().id();
        let same_thread = std::sync::Mutex::new(true);
        pool.parallel_for(8, 1, |_r| {
            if std::thread::current().id() != caller {
                *same_thread.lock().unwrap() = false;
            }
        });
        assert!(*same_thread.lock().unwrap());
        // And no workers were ever spawned for it.
        assert!(pool.workers.lock().unwrap().is_empty());
    }

    #[test]
    fn parallel_for_mut_views_are_disjoint_and_complete() {
        let pool = ComputePool::new(4, "test-mut");
        let total = 10_000;
        let width = 7;
        let mut out = vec![0u64; total * width];
        pool.parallel_for_mut(total, 64, &mut out, |r, view| {
            assert_eq!(view.len(), r.len() * width);
            for (j, v) in view.iter_mut().enumerate() {
                *v = (r.start * width + j) as u64;
            }
        });
        for (i, &v) in out.iter().enumerate() {
            assert_eq!(v, i as u64);
        }
    }

    #[test]
    fn chunking_independent_of_results() {
        // Same deterministic function under 1, 2, 8 threads → same bytes.
        let compute = |threads: usize| -> Vec<f32> {
            let pool = ComputePool::new(threads, "test-det");
            let n = 65_536;
            let mut out = vec![0f32; n];
            pool.parallel_for_mut(n, 8, &mut out, |r, view| {
                for (j, v) in view.iter_mut().enumerate() {
                    let i = r.start + j;
                    *v = ((i as f32) * 0.001).sin();
                }
            });
            out
        };
        let a = compute(1);
        assert_eq!(a, compute(2));
        assert_eq!(a, compute(8));
    }

    #[test]
    fn worker_panic_propagates_to_submitter() {
        let pool = ComputePool::new(4, "test-panic");
        let r = catch_unwind(AssertUnwindSafe(|| {
            pool.parallel_for(1 << 16, 64, |_r| panic!("chunk boom"));
        }));
        let p = r.expect_err("panic must propagate");
        let msg = p.downcast_ref::<&str>().copied().unwrap_or("");
        assert_eq!(msg, "chunk boom");
        // The pool stays usable afterwards.
        let sum = AtomicU64::new(0);
        pool.parallel_for(1 << 16, 64, |r| {
            sum.fetch_add(r.len() as u64, Ordering::Relaxed);
        });
        assert_eq!(sum.load(Ordering::Relaxed), 1 << 16);
    }

    #[test]
    fn nested_parallel_for_runs_inline() {
        let pool = Arc::new(ComputePool::new(4, "test-nested"));
        let inner_pool = Arc::clone(&pool);
        let count = AtomicU64::new(0);
        pool.parallel_for(1 << 16, 64, |r| {
            // A nested call from a worker must not deadlock.
            inner_pool.parallel_for(4, 20_000, |rr| {
                count.fetch_add(rr.len() as u64, Ordering::Relaxed);
            });
            let _ = r;
        });
        assert!(count.load(Ordering::Relaxed) > 0);
    }

    #[test]
    fn serial_pool_is_inline_always() {
        let pool = ComputePool::serial();
        let caller = std::thread::current().id();
        let ok = std::sync::Mutex::new(true);
        pool.parallel_for(1 << 20, 64, |_r| {
            if std::thread::current().id() != caller {
                *ok.lock().unwrap() = false;
            }
        });
        assert!(*ok.lock().unwrap());
    }

    #[test]
    fn concurrent_jobs_from_many_threads() {
        let pool = Arc::new(ComputePool::new(4, "test-concurrent"));
        std::thread::scope(|s| {
            for t in 0..6 {
                let pool = Arc::clone(&pool);
                s.spawn(move || {
                    let sum = AtomicU64::new(0);
                    pool.parallel_for(40_000, 8, |r| {
                        sum.fetch_add(r.map(|i| i as u64).sum::<u64>(), Ordering::Relaxed);
                    });
                    let n = 40_000u64;
                    assert_eq!(sum.load(Ordering::Relaxed), n * (n - 1) / 2, "thread {t}");
                });
            }
        });
    }
}
