//! Devices (§3 "Devices"): "each device has a device type and a name …
//! composed of pieces that identify the device's type, the device's index
//! within the worker, and, in our distributed setting, an identification
//! of the job and task of the worker".
//!
//! This testbed has one physical CPU; heterogeneity is reproduced with
//! *virtual devices*: each device gets its own kernel thread pool and
//! allocator statistics, and the §3.2.1 cost model assigns per-device-type
//! relative speeds so the placement problem stays non-trivial.

pub mod compute_pool;
pub mod spec;

pub use compute_pool::ComputePool;
pub use spec::{DeviceSpec, PartialDeviceSpec};

use crate::error::{Result, Status};
use crate::util::threadpool::ThreadPool;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Arc;

/// Allocator statistics per device ("each device object is responsible for
/// managing allocation and deallocation of device memory"). Tensors are
/// host Vecs here, so the stats track logical tensor bytes registered by
/// the executor — which is exactly what the §5.2 peak-memory experiment
/// measures.
#[derive(Debug, Default)]
pub struct AllocatorStats {
    live_bytes: AtomicU64,
    peak_bytes: AtomicU64,
    total_allocs: AtomicU64,
}

impl AllocatorStats {
    pub fn alloc(&self, bytes: usize) {
        self.total_allocs.fetch_add(1, Ordering::Relaxed);
        let live = self.live_bytes.fetch_add(bytes as u64, Ordering::Relaxed) + bytes as u64;
        self.peak_bytes.fetch_max(live, Ordering::Relaxed);
    }

    pub fn dealloc(&self, bytes: usize) {
        self.live_bytes.fetch_sub(bytes as u64, Ordering::Relaxed);
    }

    pub fn live_bytes(&self) -> u64 {
        self.live_bytes.load(Ordering::Relaxed)
    }

    pub fn peak_bytes(&self) -> u64 {
        self.peak_bytes.load(Ordering::Relaxed)
    }

    pub fn total_allocs(&self) -> u64 {
        self.total_allocs.load(Ordering::Relaxed)
    }

    pub fn reset_peak(&self) {
        self.peak_bytes.store(self.live_bytes.load(Ordering::Relaxed), Ordering::Relaxed);
    }
}

/// A computational device: name + two thread pools + allocator stats.
///
/// The pools split exactly as the OSDI'16 runtime knob does: `pool` is
/// the *inter-op* pool dispatching ready nodes (§3.1), `compute` is the
/// *intra-op* pool a single kernel's `parallel_for` fans out over —
/// distinct so a long node never starves kernel-internal data
/// parallelism and vice versa.
pub struct Device {
    pub spec: DeviceSpec,
    /// Inter-op: executes ready graph nodes.
    pub pool: ThreadPool,
    /// Intra-op: data parallelism inside one kernel (`parallel_for`).
    pub compute: ComputePool,
    pub stats: AllocatorStats,
}

impl Device {
    /// A device with `threads` inter-op threads and serial kernels
    /// (intra-op parallelism of 1).
    pub fn new(spec: DeviceSpec, threads: usize) -> Device {
        Device::with_intra_op(spec, threads, 1)
    }

    /// A device with `threads` inter-op threads and an intra-op compute
    /// pool of `intra_op_threads` lanes (workers spawn lazily on first
    /// large kernel).
    pub fn with_intra_op(spec: DeviceSpec, threads: usize, intra_op_threads: usize) -> Device {
        let name = format!("dev-{}-{}", spec.device_type, spec.index);
        Device {
            spec,
            pool: ThreadPool::new(threads, &name),
            compute: ComputePool::new(intra_op_threads, &format!("{name}-intra")),
            stats: AllocatorStats::default(),
        }
    }

    pub fn name(&self) -> String {
        self.spec.to_string()
    }

    pub fn device_type(&self) -> &str {
        &self.spec.device_type
    }
}

impl std::fmt::Debug for Device {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "Device({})", self.spec)
    }
}

/// The set of devices managed by one worker process ("each worker process
/// [is] responsible for arbitrating access to one or more computational
/// devices").
#[derive(Debug, Clone, Default)]
pub struct DeviceSet {
    devices: Vec<Arc<Device>>,
}

impl DeviceSet {
    pub fn new(devices: Vec<Arc<Device>>) -> DeviceSet {
        DeviceSet { devices }
    }

    /// A local single-process device set: `/job:localhost/task:0/device:cpu:i`.
    pub fn local(num_devices: usize, threads_per_device: usize) -> DeviceSet {
        DeviceSet::local_with_intra_op(num_devices, threads_per_device, 1)
    }

    /// [`DeviceSet::local`] with each device's intra-op compute pool
    /// sized to `intra_op_threads` (`SessionOptions::intra_op_threads`).
    pub fn local_with_intra_op(
        num_devices: usize,
        threads_per_device: usize,
        intra_op_threads: usize,
    ) -> DeviceSet {
        let devices = (0..num_devices)
            .map(|i| {
                Arc::new(Device::with_intra_op(
                    DeviceSpec::local_cpu(i),
                    threads_per_device,
                    intra_op_threads,
                ))
            })
            .collect();
        DeviceSet { devices }
    }

    pub fn len(&self) -> usize {
        self.devices.len()
    }

    pub fn is_empty(&self) -> bool {
        self.devices.is_empty()
    }

    pub fn devices(&self) -> &[Arc<Device>] {
        &self.devices
    }

    pub fn get(&self, i: usize) -> &Arc<Device> {
        &self.devices[i]
    }

    pub fn find_by_name(&self, name: &str) -> Result<Arc<Device>> {
        let spec = DeviceSpec::parse(name)?;
        self.devices
            .iter()
            .find(|d| d.spec == spec)
            .cloned()
            .ok_or_else(|| Status::not_found(format!("device {name:?} not in device set")))
    }

    /// Devices matching a partial constraint (§4.3), e.g.
    /// "/job:worker/task:17" matches all of that task's devices.
    pub fn matching(&self, partial: &PartialDeviceSpec) -> Vec<Arc<Device>> {
        self.devices.iter().filter(|d| partial.matches(&d.spec)).cloned().collect()
    }

    pub fn names(&self) -> Vec<String> {
        self.devices.iter().map(|d| d.name()).collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn local_device_set() {
        let ds = DeviceSet::local(3, 2);
        assert_eq!(ds.len(), 3);
        assert_eq!(ds.get(0).name(), "/job:localhost/task:0/device:cpu:0");
        assert!(ds.find_by_name("/job:localhost/task:0/device:cpu:2").is_ok());
        assert!(ds.find_by_name("/job:localhost/task:0/device:cpu:9").is_err());
    }

    #[test]
    fn matching_partial() {
        let ds = DeviceSet::local(4, 1);
        let p = PartialDeviceSpec::parse("/device:cpu:1").unwrap();
        let m = ds.matching(&p);
        assert_eq!(m.len(), 1);
        assert_eq!(m[0].spec.index, 1);
        let all = PartialDeviceSpec::parse("/job:localhost").unwrap();
        assert_eq!(ds.matching(&all).len(), 4);
    }

    #[test]
    fn intra_op_pool_sized_by_constructor() {
        let d = Device::new(DeviceSpec::local_cpu(0), 2);
        assert_eq!(d.compute.threads(), 1);
        let d4 = Device::with_intra_op(DeviceSpec::local_cpu(1), 2, 4);
        assert_eq!(d4.compute.threads(), 4);
        let ds = DeviceSet::local_with_intra_op(2, 1, 3);
        assert!(ds.devices().iter().all(|d| d.compute.threads() == 3));
    }

    #[test]
    fn allocator_stats_track_peak() {
        let s = AllocatorStats::default();
        s.alloc(100);
        s.alloc(50);
        s.dealloc(100);
        s.alloc(10);
        assert_eq!(s.live_bytes(), 60);
        assert_eq!(s.peak_bytes(), 150);
        assert_eq!(s.total_allocs(), 3);
        s.reset_peak();
        assert_eq!(s.peak_bytes(), 60);
    }
}
