//! The continuous profiler (§9.2 EEG, "tfprof"-style rollups).
//!
//! The whitepaper's §9.2 tooling visualizes *one* step; production
//! debugging needs the aggregate view — which nodes dominate self-time
//! across the last N steps, how step latency is distributed, where the
//! memory watermark sits, and (for synchronous data-parallel training,
//! §4.4/Fig 7) which replica is the straggler. [`Profiler`] is that
//! aggregate: a bounded ring of the last N [`StepStats`] folded on demand
//! into per-node and per-op-type [`Rollup`]s, plus persistent phase
//! rollups ([`Profiler::observe_span`]) for coarse non-step spans like
//! the trainer's pull/compute/push phases.
//!
//! Everything is recomputed from the ring at report time, so reports are
//! a pure function of the observed steps — deterministic and cheap to
//! test. Feeding the profiler is O(1) per step (an `Arc` push plus one
//! histogram record); nothing on the step path ever walks the ring.
//!
//! [`straggler_report`] closes the loop for distributed training: it
//! scans a [`MetricsRegistry`] for the parameter server's per-replica
//! `ps/replica<i>/barrier_wait_us` histograms (sync-mode barrier arrival
//! lag — see `distributed::ps`) and names the replica whose p95 lag is
//! largest. A straggler is identified from the histograms alone, with no
//! cooperation from the slow worker.

use crate::tracing_tools::StepStats;
use crate::util::json::Json;
use crate::util::stats::{LatencyHistogram, LatencySummary};
use std::collections::{BTreeMap, VecDeque};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex};
use std::time::Duration;

use super::MetricsRegistry;

/// One aggregated row of the profile: a node, an op type, or a phase
/// span, folded across the profiler's window.
#[derive(Debug, Clone, Default, PartialEq)]
pub struct Rollup {
    /// Node name, op type, or span name depending on which report this
    /// row came from.
    pub name: String,
    /// Op type of the row (equals `name` in the per-op report).
    pub op: String,
    /// Executions across the window.
    pub count: u64,
    /// Accumulated self-time, µs.
    pub total_us: u64,
    /// Median of the row's per-step mean self-times, µs.
    pub p50_us: u64,
    /// 95th percentile of the row's per-step mean self-times, µs.
    pub p95_us: u64,
    /// Peak output bytes seen for the row in any window step.
    pub peak_bytes: u64,
    /// This row's fraction of all self-time in the window, in [0, 1].
    pub share: f64,
}

impl Rollup {
    pub fn mean_us(&self) -> u64 {
        if self.count == 0 {
            0
        } else {
            self.total_us / self.count
        }
    }
}

/// Persistent (non-windowed) accumulator for one named phase span.
struct SpanRollup {
    op: String,
    count: u64,
    total_us: u64,
    hist: LatencyHistogram,
}

/// Aggregating profiler: last-N-steps ring + step-latency histogram +
/// persistent phase spans. Shared via `Arc`; every method takes `&self`.
pub struct Profiler {
    window: usize,
    ring: Mutex<VecDeque<Arc<StepStats>>>,
    steps_observed: AtomicU64,
    step_latency: LatencyHistogram,
    spans: Mutex<BTreeMap<String, SpanRollup>>,
}

impl Profiler {
    /// A profiler keeping the last `window` steps (clamped to ≥ 1).
    pub fn new(window: usize) -> Arc<Profiler> {
        Arc::new(Profiler {
            window: window.max(1),
            ring: Mutex::new(VecDeque::new()),
            steps_observed: AtomicU64::new(0),
            step_latency: LatencyHistogram::new(),
            spans: Mutex::new(BTreeMap::new()),
        })
    }

    pub fn window(&self) -> usize {
        self.window
    }

    /// Steps currently held in the ring (≤ `window`).
    pub fn window_len(&self) -> usize {
        self.ring.lock().unwrap().len()
    }

    /// Total steps ever observed (including evicted ones).
    pub fn steps_observed(&self) -> u64 {
        self.steps_observed.load(Ordering::Relaxed)
    }

    /// Fold one step's stats in. O(1): push + maybe evict + one
    /// histogram record of the step's total self-time.
    pub fn observe(&self, stats: Arc<StepStats>) {
        self.step_latency.record(Duration::from_micros(stats.total_us()));
        self.steps_observed.fetch_add(1, Ordering::Relaxed);
        let mut ring = self.ring.lock().unwrap();
        ring.push_back(stats);
        while ring.len() > self.window {
            ring.pop_front();
        }
    }

    /// Record one execution of a named coarse phase (e.g. the trainer's
    /// `replica/pull`). Phase rollups are persistent, not windowed: they
    /// are few, cheap, and most useful as lifetime aggregates.
    pub fn observe_span(&self, name: &str, op: &str, dur: Duration) {
        let mut spans = self.spans.lock().unwrap();
        let s = spans.entry(name.to_string()).or_insert_with(|| SpanRollup {
            op: op.to_string(),
            count: 0,
            total_us: 0,
            hist: LatencyHistogram::new(),
        });
        s.count += 1;
        s.total_us += dur.as_micros().min(u64::MAX as u128) as u64;
        s.hist.record(dur);
    }

    /// Distribution of per-step total self-time across everything ever
    /// observed (histogram-backed, so percentiles are bucket-resolution).
    pub fn step_latency(&self) -> LatencySummary {
        self.step_latency.summary()
    }

    /// Per-node rollups across the window, sorted by total self-time
    /// descending (ties broken by name for determinism).
    pub fn node_rollups(&self) -> Vec<Rollup> {
        self.rollups_by(|n| n.name.clone())
    }

    /// Per-op-type rollups across the window, sorted by total self-time
    /// descending.
    pub fn op_rollups(&self) -> Vec<Rollup> {
        self.rollups_by(|n| n.op.clone())
    }

    fn rollups_by(&self, key: impl Fn(&crate::tracing_tools::NodeStats) -> String) -> Vec<Rollup> {
        let ring = self.ring.lock().unwrap();
        // (rollup, per-step mean samples) per key.
        let mut acc: BTreeMap<String, (Rollup, Vec<u64>)> = BTreeMap::new();
        let mut window_total = 0u64;
        for step in ring.iter() {
            for n in &step.nodes {
                window_total += n.total_us;
                let k = key(n);
                let e = acc.entry(k.clone()).or_insert_with(|| {
                    (Rollup { name: k, op: n.op.clone(), ..Rollup::default() }, Vec::new())
                });
                e.0.count += n.count;
                e.0.total_us += n.total_us;
                e.0.peak_bytes = e.0.peak_bytes.max(n.peak_bytes);
                e.1.push(n.mean_us());
            }
        }
        let mut out: Vec<Rollup> = acc
            .into_values()
            .map(|(mut r, mut samples)| {
                samples.sort_unstable();
                r.p50_us = nearest_rank(&samples, 0.50);
                r.p95_us = nearest_rank(&samples, 0.95);
                r.share = if window_total == 0 {
                    0.0
                } else {
                    r.total_us as f64 / window_total as f64
                };
                r
            })
            .collect();
        out.sort_by(|a, b| b.total_us.cmp(&a.total_us).then_with(|| a.name.cmp(&b.name)));
        out
    }

    /// Top-k nodes by total self-time.
    pub fn top_nodes(&self, k: usize) -> Vec<Rollup> {
        let mut v = self.node_rollups();
        v.truncate(k);
        v
    }

    /// Top-k nodes by peak output bytes — the memory-attribution view.
    pub fn top_bytes(&self, k: usize) -> Vec<Rollup> {
        let mut v = self.node_rollups();
        v.sort_by(|a, b| b.peak_bytes.cmp(&a.peak_bytes).then_with(|| a.name.cmp(&b.name)));
        v.truncate(k);
        v
    }

    /// Phase-span rollups (lifetime), sorted by total time descending.
    pub fn span_rollups(&self) -> Vec<Rollup> {
        let spans = self.spans.lock().unwrap();
        let total: u64 = spans.values().map(|s| s.total_us).sum();
        let mut out: Vec<Rollup> = spans
            .iter()
            .map(|(name, s)| Rollup {
                name: name.clone(),
                op: s.op.clone(),
                count: s.count,
                total_us: s.total_us,
                p50_us: s.hist.quantile(0.50).as_micros() as u64,
                p95_us: s.hist.quantile(0.95).as_micros() as u64,
                peak_bytes: 0,
                share: if total == 0 { 0.0 } else { s.total_us as f64 / total as f64 },
            })
            .collect();
        out.sort_by(|a, b| b.total_us.cmp(&a.total_us).then_with(|| a.name.cmp(&b.name)));
        out
    }

    /// Memory reports of the newest step in the window (device, planner
    /// stats, arena counters, per-step byte high-watermark).
    pub fn latest_memory(&self) -> Vec<crate::memory::MemoryReport> {
        self.ring.lock().unwrap().back().map(|s| s.memory.clone()).unwrap_or_default()
    }

    /// tfprof-style text report: step-latency percentiles, top-k nodes by
    /// self-time, top-k ops, top-k nodes by bytes, phase spans, and the
    /// newest step's memory watermarks. This is what `/statusz` serves.
    pub fn report_text(&self, k: usize) -> String {
        let us = |d: Duration| d.as_micros() as u64;
        let mut out = String::new();
        let lat = self.step_latency();
        out.push_str(&format!(
            "== profile: window {} of {} observed steps ==\n",
            self.window_len(),
            self.steps_observed()
        ));
        out.push_str(&format!(
            "step latency: count={} mean={}us p50={}us p95={}us p99={}us max={}us\n",
            lat.count,
            us(lat.mean),
            us(lat.p50),
            us(lat.p95),
            us(lat.p99),
            us(lat.max)
        ));
        let section = |out: &mut String, title: &str, rows: &[Rollup]| {
            if rows.is_empty() {
                return;
            }
            out.push_str(title);
            out.push('\n');
            for r in rows {
                out.push_str(&format!(
                    "  {} ({}) count={} total={}us mean={}us p50={}us p95={}us share={:.1}% peak={}B\n",
                    r.name,
                    r.op,
                    r.count,
                    r.total_us,
                    r.mean_us(),
                    r.p50_us,
                    r.p95_us,
                    r.share * 100.0,
                    r.peak_bytes
                ));
            }
        };
        section(&mut out, "top nodes by self time:", &self.top_nodes(k));
        section(&mut out, "top ops by self time:", &{
            let mut v = self.op_rollups();
            v.truncate(k);
            v
        });
        section(&mut out, "top nodes by peak bytes:", &self.top_bytes(k));
        section(&mut out, "phases:", &self.span_rollups());
        let mem = self.latest_memory();
        if !mem.is_empty() {
            out.push_str("memory (per executor, peak step bytes):\n");
            for m in &mem {
                out.push_str(&format!(
                    "  {}: planned={}B dynamic={}B scratch={}B total={}B\n",
                    m.device,
                    m.high_water.planned_bytes,
                    m.high_water.dynamic_bytes,
                    m.high_water.scratch_bytes,
                    m.high_water.total_bytes()
                ));
            }
        }
        out
    }

    /// The same report as a [`Json`] object (for `/statusz?format=json`
    /// and tests).
    pub fn report_json(&self, k: usize) -> Json {
        let us = |d: Duration| d.as_micros() as u64;
        let lat = self.step_latency();
        let rows = |rows: Vec<Rollup>| {
            let mut arr = Json::arr();
            for r in rows {
                arr.push(
                    Json::obj()
                        .set("name", r.name.clone())
                        .set("op", r.op.clone())
                        .set("count", r.count)
                        .set("total_us", r.total_us)
                        .set("mean_us", r.mean_us())
                        .set("p50_us", r.p50_us)
                        .set("p95_us", r.p95_us)
                        .set("share", r.share)
                        .set("peak_bytes", r.peak_bytes),
                );
            }
            arr
        };
        let mut mem = Json::arr();
        for m in self.latest_memory() {
            mem.push(
                Json::obj()
                    .set("device", m.device.clone())
                    .set("hw_planned_bytes", m.high_water.planned_bytes)
                    .set("hw_dynamic_bytes", m.high_water.dynamic_bytes)
                    .set("hw_scratch_bytes", m.high_water.scratch_bytes),
            );
        }
        Json::obj()
            .set("window", self.window_len() as u64)
            .set("steps_observed", self.steps_observed())
            .set(
                "step_latency",
                Json::obj()
                    .set("count", lat.count)
                    .set("mean_us", us(lat.mean))
                    .set("p50_us", us(lat.p50))
                    .set("p95_us", us(lat.p95))
                    .set("p99_us", us(lat.p99))
                    .set("max_us", us(lat.max)),
            )
            .set("nodes", rows(self.top_nodes(k)))
            .set("ops", rows({
                let mut v = self.op_rollups();
                v.truncate(k);
                v
            }))
            .set("by_bytes", rows(self.top_bytes(k)))
            .set("phases", rows(self.span_rollups()))
            .set("memory", mem)
    }
}

/// Nearest-rank percentile over pre-sorted samples.
fn nearest_rank(sorted: &[u64], q: f64) -> u64 {
    if sorted.is_empty() {
        return 0;
    }
    let rank = (q * sorted.len() as f64).ceil().max(1.0) as usize;
    sorted[rank.min(sorted.len()) - 1]
}

/// One replica's barrier-arrival-lag distribution, from the parameter
/// server's `ps/replica<i>/barrier_wait_us` histogram.
#[derive(Debug, Clone)]
pub struct ReplicaWait {
    pub replica: usize,
    /// The registry name the histogram was found under.
    pub name: String,
    pub count: u64,
    pub p50_us: u64,
    pub p95_us: u64,
}

/// Straggler verdict for one sync-training group: every replica's lag
/// distribution plus the index of the slowest (largest p95 lag).
#[derive(Debug, Clone)]
pub struct StragglerReport {
    /// Sorted by replica index.
    pub replicas: Vec<ReplicaWait>,
    /// Replica index with the largest p95 arrival lag.
    pub slowest: usize,
}

impl StragglerReport {
    pub fn slowest_wait(&self) -> Option<&ReplicaWait> {
        self.replicas.iter().find(|r| r.replica == self.slowest)
    }

    pub fn render_text(&self) -> String {
        let mut out = String::from("sync replicas by barrier arrival lag:\n");
        for r in &self.replicas {
            let tag = if r.replica == self.slowest { "  <-- straggler" } else { "" };
            out.push_str(&format!(
                "  replica {}: count={} p50={}us p95={}us{}\n",
                r.replica, r.count, r.p50_us, r.p95_us, tag
            ));
        }
        out
    }

    pub fn to_json(&self) -> Json {
        let mut arr = Json::arr();
        for r in &self.replicas {
            arr.push(
                Json::obj()
                    .set("replica", r.replica as u64)
                    .set("count", r.count)
                    .set("p50_us", r.p50_us)
                    .set("p95_us", r.p95_us),
            );
        }
        Json::obj().set("slowest", self.slowest as u64).set("replicas", arr)
    }
}

/// Scan a registry for `ps/replica<i>/barrier_wait_us` histograms and
/// name the straggler — the replica whose p95 barrier arrival lag is
/// largest (ties broken toward the lower index). `None` when no replica
/// has recorded a lag yet.
pub fn straggler_report(registry: &MetricsRegistry) -> Option<StragglerReport> {
    let mut replicas: Vec<ReplicaWait> = registry
        .histograms()
        .into_iter()
        .filter_map(|(name, h)| {
            let idx: usize = name
                .strip_prefix("ps/replica")?
                .strip_suffix("/barrier_wait_us")?
                .parse()
                .ok()?;
            if h.count() == 0 {
                return None;
            }
            Some(ReplicaWait {
                replica: idx,
                name,
                count: h.count(),
                p50_us: h.quantile(0.50).as_micros() as u64,
                p95_us: h.quantile(0.95).as_micros() as u64,
            })
        })
        .collect();
    if replicas.is_empty() {
        return None;
    }
    replicas.sort_by_key(|r| r.replica);
    let slowest = replicas
        .iter()
        .max_by(|a, b| a.p95_us.cmp(&b.p95_us).then(b.replica.cmp(&a.replica)))
        .map(|r| r.replica)?;
    Some(StragglerReport { replicas, slowest })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tracing_tools::NodeStats;

    fn step(id: u64, rows: &[(&str, &str, u64, u64, u64)]) -> Arc<StepStats> {
        let nodes = rows
            .iter()
            .map(|(name, op, total, count, bytes)| NodeStats {
                name: name.to_string(),
                op: op.to_string(),
                device: "cpu:0".into(),
                total_us: *total,
                count: *count,
                peak_bytes: *bytes,
            })
            .collect();
        Arc::new(StepStats { step_id: id, nodes, memory: Vec::new() })
    }

    #[test]
    fn rollups_fold_window_deterministically() {
        let p = Profiler::new(8);
        p.observe(step(1, &[("mm", "MatMul", 300, 1, 4096), ("add", "Add", 100, 2, 64)]));
        p.observe(step(2, &[("mm", "MatMul", 500, 1, 8192), ("add", "Add", 100, 2, 64)]));
        assert_eq!(p.steps_observed(), 2);
        assert_eq!(p.window_len(), 2);

        let nodes = p.node_rollups();
        assert_eq!(nodes[0].name, "mm");
        assert_eq!(nodes[0].total_us, 800);
        assert_eq!(nodes[0].count, 2);
        assert_eq!(nodes[0].peak_bytes, 8192);
        // Per-step means 300 and 500 → p50 = 300, p95 = 500 (nearest rank).
        assert_eq!(nodes[0].p50_us, 300);
        assert_eq!(nodes[0].p95_us, 500);
        assert!((nodes[0].share - 0.8).abs() < 1e-9, "{}", nodes[0].share);
        assert_eq!(nodes[1].name, "add");
        assert_eq!(nodes[1].total_us, 200);

        // Identical reports on repeated calls (pure function of the ring).
        assert_eq!(p.node_rollups(), nodes);
        let ops = p.op_rollups();
        assert_eq!(ops[0].name, "MatMul");
        assert_eq!(ops[1].count, 4);

        let by_bytes = p.top_bytes(1);
        assert_eq!(by_bytes[0].name, "mm");

        let text = p.report_text(5);
        assert!(text.contains("mm (MatMul)"), "{text}");
        assert!(text.contains("share=80.0%"), "{text}");
        let j = p.report_json(5);
        assert_eq!(j.get("steps_observed").and_then(Json::as_i64), Some(2));
        assert_eq!(
            j.get("nodes").and_then(Json::as_array).unwrap()[0].get("name").and_then(Json::as_str),
            Some("mm")
        );
    }

    #[test]
    fn ring_evicts_beyond_window() {
        let p = Profiler::new(2);
        for i in 0..5 {
            p.observe(step(i, &[("n", "Op", 10 * (i + 1), 1, 0)]));
        }
        assert_eq!(p.steps_observed(), 5);
        assert_eq!(p.window_len(), 2);
        // Only steps 3 and 4 remain: totals 40 + 50.
        assert_eq!(p.node_rollups()[0].total_us, 90);
        // Step latency is lifetime, not windowed.
        assert_eq!(p.step_latency().count, 5);
    }

    #[test]
    fn span_rollups_accumulate() {
        let p = Profiler::new(4);
        p.observe_span("replica/pull", "phase", Duration::from_micros(100));
        p.observe_span("replica/pull", "phase", Duration::from_micros(300));
        p.observe_span("replica/push", "phase", Duration::from_micros(50));
        let spans = p.span_rollups();
        assert_eq!(spans[0].name, "replica/pull");
        assert_eq!(spans[0].count, 2);
        assert_eq!(spans[0].total_us, 400);
        assert_eq!(spans[1].name, "replica/push");
        assert!(p.report_text(5).contains("replica/pull"), "{}", p.report_text(5));
    }

    #[test]
    fn straggler_named_from_histograms_alone() {
        let r = MetricsRegistry::new();
        for i in 0..3usize {
            let h = r.histogram(&format!("ps/replica{i}/barrier_wait_us"));
            // Replica 1 lags ~20ms behind; the others arrive promptly.
            let lag = if i == 1 { 20_000 } else { 50 };
            for _ in 0..10 {
                h.record(Duration::from_micros(lag));
            }
        }
        // Unrelated histograms don't confuse the scan.
        r.histogram("wire/PUSH/lat_us").record(Duration::from_micros(1_000_000));
        let rep = straggler_report(&r).unwrap();
        assert_eq!(rep.replicas.len(), 3);
        assert_eq!(rep.slowest, 1);
        let slow = rep.slowest_wait().unwrap();
        let fast = &rep.replicas[0];
        assert!(
            slow.p95_us > 10 * fast.p95_us.max(1),
            "straggler p95 {} must dwarf fast p95 {}",
            slow.p95_us,
            fast.p95_us
        );
        assert!(rep.render_text().contains("replica 1"), "{}", rep.render_text());
        assert!(rep.render_text().contains("<-- straggler"), "{}", rep.render_text());
    }

    #[test]
    fn empty_profiler_reports_cleanly() {
        let p = Profiler::new(4);
        assert_eq!(p.node_rollups(), Vec::new());
        assert!(p.report_text(5).contains("window 0 of 0"));
        assert!(straggler_report(&MetricsRegistry::new()).is_none());
    }
}
