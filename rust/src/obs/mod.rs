//! Unified observability: a process-wide metrics registry.
//!
//! Every server in the crate used to grow its own ad-hoc stats — the
//! parameter server carried four loose `AtomicU64`s, the serving manager
//! its own `VersionCounters`, the wire layer nothing at all. This module
//! gives them one shared substrate: named [`Counter`]s, [`Gauge`]s, and
//! [`LatencyHistogram`]s behind a [`MetricsRegistry`], with a single
//! JSON/text exporter so `MSG_STATS` and `MSG_PS_STATS` serve the same
//! shape of dump.
//!
//! Hot paths are lock-free: registration (`counter`/`gauge`/`histogram`)
//! takes the registry lock once and hands back an `Arc` handle; every
//! subsequent `inc`/`add`/`record` is a relaxed atomic op on that handle.
//! Callers cache handles, not names.
//!
//! Names are slash-separated paths (`"wire/PS_PUSH/bytes_in"`,
//! `"serving/m/v1/requests"`). The registry imposes no schema beyond
//! "one kind per name": asking for a `counter` where a `gauge` is
//! registered returns a detached handle (still functional, never
//! exported twice) and bumps `obs/kind_conflicts` so the bug is visible
//! in the dump itself.

pub mod httpz;
pub mod profiler;

use crate::util::json::Json;
use crate::util::stats::LatencyHistogram;
use std::collections::BTreeMap;
use std::sync::atomic::{AtomicI64, AtomicU64, Ordering};
use std::sync::{Arc, Mutex, OnceLock};

/// Monotonic counter. All ops are relaxed atomics.
#[derive(Debug, Default)]
pub struct Counter(AtomicU64);

impl Counter {
    pub fn new() -> Counter {
        Counter::default()
    }

    pub fn inc(&self) {
        self.0.fetch_add(1, Ordering::Relaxed);
    }

    pub fn add(&self, n: u64) {
        self.0.fetch_add(n, Ordering::Relaxed);
    }

    pub fn get(&self) -> u64 {
        self.0.load(Ordering::Relaxed)
    }
}

/// Point-in-time signed value (queue depths, in-flight requests).
#[derive(Debug, Default)]
pub struct Gauge(AtomicI64);

impl Gauge {
    pub fn new() -> Gauge {
        Gauge::default()
    }

    pub fn set(&self, v: i64) {
        self.0.store(v, Ordering::Relaxed);
    }

    pub fn add(&self, n: i64) {
        self.0.fetch_add(n, Ordering::Relaxed);
    }

    pub fn sub(&self, n: i64) {
        self.0.fetch_sub(n, Ordering::Relaxed);
    }

    pub fn get(&self) -> i64 {
        self.0.load(Ordering::Relaxed)
    }
}

enum Metric {
    Counter(Arc<Counter>),
    Gauge(Arc<Gauge>),
    Histogram(Arc<LatencyHistogram>),
}

/// Named metrics for one exporting scope (one server, usually). The
/// registry owns the name → metric map; handles returned from
/// `counter`/`gauge`/`histogram` are `Arc`s the caller keeps, so the
/// recording path never touches the map again.
pub struct MetricsRegistry {
    metrics: Mutex<BTreeMap<String, Metric>>,
    kind_conflicts: Counter,
}

impl Default for MetricsRegistry {
    fn default() -> Self {
        MetricsRegistry { metrics: Mutex::new(BTreeMap::new()), kind_conflicts: Counter::new() }
    }
}

impl MetricsRegistry {
    pub fn new() -> Arc<MetricsRegistry> {
        Arc::new(MetricsRegistry::default())
    }

    /// Get-or-create the counter `name`. On a kind conflict (the name is
    /// registered as a gauge/histogram) returns a detached counter.
    pub fn counter(&self, name: &str) -> Arc<Counter> {
        let mut m = self.metrics.lock().unwrap();
        match m.entry(name.to_string()).or_insert_with(|| Metric::Counter(Arc::new(Counter::new())))
        {
            Metric::Counter(c) => Arc::clone(c),
            _ => {
                self.kind_conflicts.inc();
                Arc::new(Counter::new())
            }
        }
    }

    pub fn gauge(&self, name: &str) -> Arc<Gauge> {
        let mut m = self.metrics.lock().unwrap();
        match m.entry(name.to_string()).or_insert_with(|| Metric::Gauge(Arc::new(Gauge::new()))) {
            Metric::Gauge(g) => Arc::clone(g),
            _ => {
                self.kind_conflicts.inc();
                Arc::new(Gauge::new())
            }
        }
    }

    pub fn histogram(&self, name: &str) -> Arc<LatencyHistogram> {
        let mut m = self.metrics.lock().unwrap();
        match m
            .entry(name.to_string())
            .or_insert_with(|| Metric::Histogram(Arc::new(LatencyHistogram::new())))
        {
            Metric::Histogram(h) => Arc::clone(h),
            _ => {
                self.kind_conflicts.inc();
                Arc::new(LatencyHistogram::new())
            }
        }
    }

    /// Current value of a registered counter, if any — for tests and the
    /// odd cold-path read that never cached a handle.
    pub fn counter_value(&self, name: &str) -> Option<u64> {
        match self.metrics.lock().unwrap().get(name)? {
            Metric::Counter(c) => Some(c.get()),
            _ => None,
        }
    }

    /// Sum of every counter whose name passes `pred` (e.g. all
    /// `wire/*/bytes_*` counters).
    pub fn sum_counters(&self, pred: impl Fn(&str) -> bool) -> u64 {
        let m = self.metrics.lock().unwrap();
        m.iter()
            .filter_map(|(name, metric)| match metric {
                Metric::Counter(c) if pred(name) => Some(c.get()),
                _ => None,
            })
            .sum()
    }

    /// The dump as a [`Json`] object: counters and gauges as integers,
    /// histograms as `{count, mean_us, p50_us, p95_us, p99_us, max_us}`.
    /// Keys are sorted (BTreeMap order) so dumps diff cleanly.
    pub fn to_json(&self) -> Json {
        let us = |d: std::time::Duration| d.as_micros() as u64;
        let mut out = Json::obj();
        let m = self.metrics.lock().unwrap();
        for (name, metric) in m.iter() {
            match metric {
                Metric::Counter(c) => out = out.set(name, c.get()),
                Metric::Gauge(g) => out = out.set(name, g.get()),
                Metric::Histogram(h) => {
                    let s = h.summary();
                    out = out.set(
                        name,
                        Json::obj()
                            .set("count", s.count)
                            .set("mean_us", us(s.mean))
                            .set("p50_us", us(s.p50))
                            .set("p95_us", us(s.p95))
                            .set("p99_us", us(s.p99))
                            .set("max_us", us(s.max)),
                    );
                }
            }
        }
        let conflicts = self.kind_conflicts.get();
        if conflicts > 0 {
            out = out.set("obs/kind_conflicts", conflicts);
        }
        out
    }

    /// The dump rendered as a JSON string.
    pub fn export_json(&self) -> String {
        self.to_json().render()
    }

    /// Every registered histogram as `(name, handle)` pairs in name
    /// order — for exporters that need the raw buckets (the profiler's
    /// straggler scan, the `/varz` endpoint).
    pub fn histograms(&self) -> Vec<(String, Arc<LatencyHistogram>)> {
        let m = self.metrics.lock().unwrap();
        m.iter()
            .filter_map(|(name, metric)| match metric {
                Metric::Histogram(h) => Some((name.clone(), Arc::clone(h))),
                _ => None,
            })
            .collect()
    }

    /// The dump in Prometheus exposition format: `# TYPE` lines, counters
    /// and gauges as `name value`, histograms as cumulative
    /// `name_bucket{le="..."}` lines (µs bounds from the log2 buckets;
    /// all-zero buckets elided, `+Inf` always present) plus `name_sum` /
    /// `name_count`. Slash-separated registry names are sanitized to
    /// `[a-zA-Z0-9_:]` for the metric-name grammar.
    pub fn export_text(&self) -> String {
        let mut out = String::new();
        let m = self.metrics.lock().unwrap();
        for (name, metric) in m.iter() {
            let name = sanitize_metric_name(name);
            match metric {
                Metric::Counter(c) => {
                    out.push_str(&format!("# TYPE {name} counter\n{name} {}\n", c.get()))
                }
                Metric::Gauge(g) => {
                    out.push_str(&format!("# TYPE {name} gauge\n{name} {}\n", g.get()))
                }
                Metric::Histogram(h) => {
                    out.push_str(&format!("# TYPE {name} histogram\n"));
                    let counts = h.bucket_counts();
                    let mut cum = 0u64;
                    for (i, &c) in counts.iter().enumerate() {
                        if c == 0 {
                            continue;
                        }
                        cum += c;
                        // The final catch-all bucket folds into +Inf below.
                        if let Some(le) = LatencyHistogram::bucket_upper_micros(i) {
                            out.push_str(&format!("{name}_bucket{{le=\"{le}\"}} {cum}\n"));
                        }
                    }
                    out.push_str(&format!("{name}_bucket{{le=\"+Inf\"}} {cum}\n"));
                    out.push_str(&format!("{name}_sum {}\n", h.sum_micros()));
                    out.push_str(&format!("{name}_count {}\n", h.count()));
                }
            }
        }
        out
    }
}

/// Map a slash-separated registry name onto the Prometheus metric-name
/// grammar `[a-zA-Z_:][a-zA-Z0-9_:]*`: invalid characters become `_`, a
/// leading digit gets a `_` prefix.
fn sanitize_metric_name(name: &str) -> String {
    let mut out = String::with_capacity(name.len());
    for (i, ch) in name.chars().enumerate() {
        let ok = ch.is_ascii_alphabetic()
            || ch == '_'
            || ch == ':'
            || (ch.is_ascii_digit() && i > 0);
        if ch.is_ascii_digit() && i == 0 {
            out.push('_');
            out.push(ch);
        } else if ok {
            out.push(ch);
        } else {
            out.push('_');
        }
    }
    out
}

/// The process-wide default registry: for code without a natural owning
/// scope. Servers (the parameter server, the model manager) each own
/// their *own* registry so two instances in one process don't collide.
pub fn global() -> &'static Arc<MetricsRegistry> {
    static GLOBAL: OnceLock<Arc<MetricsRegistry>> = OnceLock::new();
    GLOBAL.get_or_init(MetricsRegistry::new)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::json::Json;

    #[test]
    fn counters_and_gauges_register_once() {
        let r = MetricsRegistry::new();
        let c1 = r.counter("a/b");
        let c2 = r.counter("a/b");
        c1.add(3);
        c2.inc();
        assert_eq!(r.counter_value("a/b"), Some(4));
        let g = r.gauge("depth");
        g.set(5);
        g.sub(2);
        assert_eq!(g.get(), 3);
    }

    #[test]
    fn kind_conflict_returns_detached_handle() {
        let r = MetricsRegistry::new();
        let _g = r.gauge("x");
        let c = r.counter("x"); // wrong kind: detached, never exported
        c.add(100);
        assert_eq!(r.counter_value("x"), None);
        let dump = r.export_json();
        assert!(dump.contains("\"obs/kind_conflicts\":1"), "{dump}");
    }

    #[test]
    fn export_json_parses_and_has_histogram_fields() {
        let r = MetricsRegistry::new();
        r.counter("reqs").add(7);
        r.histogram("lat").record(std::time::Duration::from_micros(250));
        let j = Json::parse(&r.export_json()).unwrap();
        assert_eq!(j.get("reqs").and_then(Json::as_i64), Some(7));
        let lat = j.get("lat").unwrap();
        assert_eq!(lat.get("count").and_then(Json::as_i64), Some(1));
        assert!(lat.get("p50_us").and_then(Json::as_i64).unwrap() >= 128);
    }

    #[test]
    fn export_text_lists_every_metric() {
        let r = MetricsRegistry::new();
        r.counter("c").inc();
        r.gauge("g").set(-2);
        r.histogram("h").record(std::time::Duration::from_micros(10));
        let t = r.export_text();
        assert!(t.contains("# TYPE c counter\nc 1\n"), "{t}");
        assert!(t.contains("# TYPE g gauge\ng -2\n"), "{t}");
        assert!(t.contains("# TYPE h histogram\n"), "{t}");
        assert!(t.contains("h_count 1\n"), "{t}");
    }

    #[test]
    fn export_text_histograms_are_prometheus_compliant() {
        let r = MetricsRegistry::new();
        let h = r.histogram("ps/replica0/barrier_wait_us");
        // 10µs → bucket 4 (le="15"); 300µs → bucket 9 (le="511").
        h.record(std::time::Duration::from_micros(10));
        h.record(std::time::Duration::from_micros(10));
        h.record(std::time::Duration::from_micros(300));
        let t = r.export_text();
        // Names sanitized to the metric-name grammar.
        assert!(t.contains("# TYPE ps_replica0_barrier_wait_us histogram\n"), "{t}");
        assert!(t.contains("ps_replica0_barrier_wait_us_bucket{le=\"15\"} 2\n"), "{t}");
        assert!(t.contains("ps_replica0_barrier_wait_us_bucket{le=\"511\"} 3\n"), "{t}");
        assert!(t.contains("ps_replica0_barrier_wait_us_bucket{le=\"+Inf\"} 3\n"), "{t}");
        assert!(t.contains("ps_replica0_barrier_wait_us_sum 320\n"), "{t}");
        assert!(t.contains("ps_replica0_barrier_wait_us_count 3\n"), "{t}");
        // Cumulative bucket counts are monotone non-decreasing and the
        // +Inf bucket equals _count (the exposition-format contract).
        let mut last = 0u64;
        let mut inf = None;
        for line in t.lines() {
            if let Some(rest) = line.strip_prefix("ps_replica0_barrier_wait_us_bucket{le=") {
                let v: u64 = rest.split("} ").nth(1).unwrap().parse().unwrap();
                assert!(v >= last, "non-monotone bucket line: {line}");
                last = v;
                if rest.starts_with("\"+Inf\"") {
                    inf = Some(v);
                }
            }
        }
        assert_eq!(inf, Some(3));
    }

    #[test]
    fn histograms_accessor_lists_registered() {
        let r = MetricsRegistry::new();
        r.histogram("a").record(std::time::Duration::from_micros(1));
        r.histogram("b").record(std::time::Duration::from_micros(2));
        r.counter("c").inc();
        let hs = r.histograms();
        assert_eq!(hs.len(), 2);
        assert_eq!(hs[0].0, "a");
        assert_eq!(hs[1].0, "b");
        assert_eq!(hs[0].1.count(), 1);
    }

    #[test]
    fn sum_counters_filters_by_name() {
        let r = MetricsRegistry::new();
        r.counter("wire/A/bytes_in").add(10);
        r.counter("wire/B/bytes_out").add(5);
        r.counter("other").add(99);
        assert_eq!(r.sum_counters(|n| n.starts_with("wire/")), 15);
    }

    #[test]
    fn global_registry_is_shared() {
        global().counter("test/global").add(2);
        assert!(global().counter_value("test/global").unwrap() >= 2);
    }
}
