//! A zero-dependency debug/status HTTP listener ("/healthz, /varz,
//! /statusz, /tracez" — the classic production-server status surface).
//!
//! This is deliberately *not* a web framework: a hand-rolled HTTP/1.0
//! GET responder sufficient for `curl` and load-balancer health checks.
//! One handler thread per connection, one request per connection
//! (`Connection: close`), bounded request heads, and a read timeout so a
//! silent client cannot park a thread. The accept loop copies the
//! hardening contract of `serving::net::NetServer`: transient accept
//! errors back off and continue, thread-spawn failure sheds the
//! connection, and only the shutdown flag (poked awake by a loopback
//! connection) ends the loop.
//!
//! Servers mount it by building a [`Routes`] table of path → handler
//! closures and calling [`DebugServer::serve`]. Handlers return a
//! [`Response`]; state (a metrics registry, a profiler, a
//! shutting-down flag) is captured by the closures, so `httpz` itself
//! depends on none of it. Malformed requests get `400`, unknown paths
//! `404` (listing the mounted routes), non-GET methods `405` — never a
//! panic, whatever the peer sends.

use crate::error::{Result, Status};
use std::collections::BTreeMap;
use std::io::{Read, Write};
use std::net::{SocketAddr, TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{Arc, Mutex};
use std::thread::JoinHandle;
use std::time::Duration;

/// Largest request head we will buffer before answering 400.
const MAX_HEAD_BYTES: usize = 8192;
/// A client gets this long to produce its request head.
const READ_TIMEOUT: Duration = Duration::from_secs(2);

/// One HTTP response: status, content type, body.
pub struct Response {
    pub status: u16,
    pub content_type: String,
    pub body: Vec<u8>,
}

impl Response {
    pub fn new(status: u16, content_type: &str, body: impl Into<Vec<u8>>) -> Response {
        Response { status, content_type: content_type.to_string(), body: body.into() }
    }

    /// `text/plain` response.
    pub fn text(status: u16, body: impl Into<String>) -> Response {
        Response::new(status, "text/plain; charset=utf-8", body.into().into_bytes())
    }

    /// `application/json` response.
    pub fn json(status: u16, body: impl Into<String>) -> Response {
        Response::new(status, "application/json", body.into().into_bytes())
    }
}

type HandlerFn = Box<dyn Fn() -> Response + Send + Sync>;

/// Path → handler table. Paths are matched exactly after stripping any
/// query string.
#[derive(Default)]
pub struct Routes {
    routes: BTreeMap<String, HandlerFn>,
}

impl Routes {
    pub fn new() -> Routes {
        Routes::default()
    }

    /// Mount `handler` at `path` (e.g. `"/healthz"`). Builder-style.
    pub fn add(
        mut self,
        path: &str,
        handler: impl Fn() -> Response + Send + Sync + 'static,
    ) -> Routes {
        self.routes.insert(path.to_string(), Box::new(handler));
        self
    }

    pub fn paths(&self) -> Vec<String> {
        self.routes.keys().cloned().collect()
    }

    fn dispatch(&self, path: &str) -> Response {
        match self.routes.get(path) {
            Some(h) => h(),
            None => {
                let mut body = format!("404: no handler for {path:?}\nmounted routes:\n");
                for p in self.routes.keys() {
                    body.push_str("  ");
                    body.push_str(p);
                    body.push('\n');
                }
                Response::text(404, body)
            }
        }
    }
}

/// A running debug listener. Dropping it (or calling
/// [`DebugServer::shutdown`]) stops accepting; in-flight handlers finish
/// their single response and close.
pub struct DebugServer {
    addr: SocketAddr,
    shutting_down: Arc<AtomicBool>,
    accept_thread: Mutex<Option<JoinHandle<()>>>,
}

impl DebugServer {
    /// Bind `addr` (e.g. `"127.0.0.1:0"`) and serve `routes` until
    /// shutdown. Returns once the listener is bound.
    pub fn serve(routes: Routes, addr: &str) -> Result<DebugServer> {
        let listener = TcpListener::bind(addr)
            .map_err(|e| Status::unavailable(format!("bind {addr}: {e}")))?;
        let local = listener.local_addr()?;
        let shutting_down = Arc::new(AtomicBool::new(false));
        let flag = Arc::clone(&shutting_down);
        let routes = Arc::new(routes);
        let accept = std::thread::Builder::new()
            .name("httpz-accept".to_string())
            .spawn(move || {
                for conn in listener.incoming() {
                    if flag.load(Ordering::SeqCst) {
                        break;
                    }
                    match conn {
                        Ok(stream) => {
                            let routes = Arc::clone(&routes);
                            let spawned = std::thread::Builder::new()
                                .name("httpz-conn".to_string())
                                .spawn(move || handle_connection(&routes, stream));
                            if spawned.is_err() {
                                // Out of threads: shed the connection
                                // rather than dying.
                                continue;
                            }
                        }
                        // Transient accept failures must not kill the
                        // listener; back off and keep accepting.
                        Err(_) => {
                            std::thread::sleep(Duration::from_millis(10));
                        }
                    }
                }
            })
            .expect("spawn httpz accept thread");
        Ok(DebugServer { addr: local, shutting_down, accept_thread: Mutex::new(Some(accept)) })
    }

    /// The bound address (resolves the ephemeral port of `":0"` binds).
    pub fn addr(&self) -> SocketAddr {
        self.addr
    }

    /// Stop accepting and join the accept loop. Idempotent.
    pub fn shutdown(&self) {
        if self.shutting_down.swap(true, Ordering::SeqCst) {
            return;
        }
        // Unblock the accept loop with a throwaway connection to our own
        // port. A wildcard bind address is not connectable; target
        // loopback on the same port instead.
        let mut wake_addr = self.addr;
        if wake_addr.ip().is_unspecified() {
            wake_addr.set_ip(match wake_addr {
                SocketAddr::V4(_) => std::net::Ipv4Addr::LOCALHOST.into(),
                SocketAddr::V6(_) => std::net::Ipv6Addr::LOCALHOST.into(),
            });
        }
        let woke = TcpStream::connect(wake_addr).is_ok();
        if let Some(h) = self.accept_thread.lock().unwrap().take() {
            if woke {
                let _ = h.join();
            }
            // If the wake failed the thread stays parked until the next
            // connection; joining would block the caller indefinitely.
        }
    }
}

impl Drop for DebugServer {
    fn drop(&mut self) {
        self.shutdown();
    }
}

/// Serve exactly one request on `stream`, whatever its quality.
fn handle_connection(routes: &Routes, mut stream: TcpStream) {
    stream.set_read_timeout(Some(READ_TIMEOUT)).ok();
    stream.set_nodelay(true).ok();
    let head = match read_head(&mut stream) {
        Ok(h) => h,
        Err(_) => {
            let _ = write_response(&mut stream, &Response::text(400, "400: bad request\n"));
            return;
        }
    };
    let response = match parse_request_line(&head) {
        Some(("GET", path)) => routes.dispatch(path),
        Some((_, _)) => Response::text(405, "405: only GET is supported\n"),
        None => Response::text(400, "400: malformed request line\n"),
    };
    let _ = write_response(&mut stream, &response);
}

/// Read until the blank line ending the request head, EOF, or the size
/// cap. Errors on oversized heads and transport failures; a truncated
/// head (EOF first) is returned as-is for the parser to reject.
fn read_head(stream: &mut TcpStream) -> std::io::Result<String> {
    let mut buf = Vec::new();
    let mut chunk = [0u8; 1024];
    loop {
        let n = stream.read(&mut chunk)?;
        if n == 0 {
            break;
        }
        buf.extend_from_slice(&chunk[..n]);
        if buf.windows(4).any(|w| w == b"\r\n\r\n") || buf.windows(2).any(|w| w == b"\n\n") {
            break;
        }
        if buf.len() > MAX_HEAD_BYTES {
            return Err(std::io::Error::new(
                std::io::ErrorKind::InvalidData,
                "request head too large",
            ));
        }
    }
    Ok(String::from_utf8_lossy(&buf).into_owned())
}

/// `"GET /statusz?k=5 HTTP/1.0" → ("GET", "/statusz")`. `None` when the
/// first line isn't `METHOD TARGET ...` with an absolute-path target.
fn parse_request_line(head: &str) -> Option<(&str, &str)> {
    let line = head.lines().next()?;
    let mut parts = line.split_whitespace();
    let method = parts.next()?;
    let target = parts.next()?;
    let path = target.split('?').next().unwrap_or(target);
    if !path.starts_with('/') {
        return None;
    }
    Some((method, path))
}

fn write_response(stream: &mut TcpStream, r: &Response) -> std::io::Result<()> {
    let reason = match r.status {
        200 => "OK",
        400 => "Bad Request",
        404 => "Not Found",
        405 => "Method Not Allowed",
        503 => "Service Unavailable",
        _ => "Status",
    };
    let head = format!(
        "HTTP/1.0 {} {}\r\nContent-Type: {}\r\nContent-Length: {}\r\nConnection: close\r\n\r\n",
        r.status,
        reason,
        r.content_type,
        r.body.len()
    );
    stream.write_all(head.as_bytes())?;
    stream.write_all(&r.body)?;
    stream.flush()
}

/// Minimal blocking GET for tests and in-process probes: one request,
/// returns `(status, body)`.
pub fn get(addr: SocketAddr, path: &str) -> Result<(u16, String)> {
    let mut stream = TcpStream::connect(addr)
        .map_err(|e| Status::unavailable(format!("connect {addr}: {e}")))?;
    stream.set_read_timeout(Some(Duration::from_secs(5))).ok();
    stream
        .write_all(format!("GET {path} HTTP/1.0\r\nHost: localhost\r\n\r\n").as_bytes())
        .map_err(|e| Status::unavailable(format!("write: {e}")))?;
    let mut raw = Vec::new();
    stream
        .read_to_end(&mut raw)
        .map_err(|e| Status::unavailable(format!("read: {e}")))?;
    let text = String::from_utf8_lossy(&raw).into_owned();
    let (head, body) = match text.find("\r\n\r\n") {
        Some(i) => (&text[..i], &text[i + 4..]),
        None => return Err(Status::internal("response missing head/body separator")),
    };
    let status: u16 = head
        .split_whitespace()
        .nth(1)
        .and_then(|s| s.parse().ok())
        .ok_or_else(|| Status::internal(format!("bad status line: {head:?}")))?;
    Ok((status, body.to_string()))
}

#[cfg(test)]
mod tests {
    use super::*;

    fn test_server() -> DebugServer {
        let healthy = Arc::new(AtomicBool::new(true));
        let h = Arc::clone(&healthy);
        let routes = Routes::new()
            .add("/healthz", move || {
                if h.load(Ordering::SeqCst) {
                    Response::text(200, "ok\n")
                } else {
                    Response::text(503, "shutting down\n")
                }
            })
            .add("/varz", || Response::text(200, "# TYPE x counter\nx 1\n"));
        DebugServer::serve(routes, "127.0.0.1:0").unwrap()
    }

    #[test]
    fn routes_serve_and_miss() {
        let srv = test_server();
        let (code, body) = get(srv.addr(), "/healthz").unwrap();
        assert_eq!((code, body.as_str()), (200, "ok\n"));
        let (code, body) = get(srv.addr(), "/varz?verbose=1").unwrap();
        assert_eq!(code, 200);
        assert!(body.contains("x 1"), "{body}");
        // Unknown path: 404 listing the mounted routes.
        let (code, body) = get(srv.addr(), "/nope").unwrap();
        assert_eq!(code, 404);
        assert!(body.contains("/healthz"), "{body}");
        srv.shutdown();
        srv.shutdown(); // idempotent
        assert!(get(srv.addr(), "/healthz").is_err(), "accept loop still alive");
    }

    #[test]
    fn hostile_requests_get_errors_not_panics() {
        let srv = test_server();
        let raw = |bytes: &[u8]| -> String {
            let mut s = TcpStream::connect(srv.addr()).unwrap();
            s.set_read_timeout(Some(Duration::from_secs(5))).ok();
            s.write_all(bytes).unwrap();
            let mut out = Vec::new();
            s.read_to_end(&mut out).ok();
            String::from_utf8_lossy(&out).into_owned()
        };
        // Non-GET method.
        assert!(raw(b"POST /healthz HTTP/1.0\r\n\r\n").starts_with("HTTP/1.0 405"));
        // Garbage request line.
        assert!(raw(b"garbage\r\n\r\n").starts_with("HTTP/1.0 400"));
        // Truncated head (EOF before the blank line).
        assert!(raw(b"GET /healthz").starts_with("HTTP/1.0 400"));
        // Oversized head.
        let mut big = Vec::from(&b"GET /healthz HTTP/1.0\r\n"[..]);
        big.extend(vec![b'a'; MAX_HEAD_BYTES + 1024]);
        let reply = raw(&big);
        // Either a 400 or a reset once the server bails — never a hang.
        assert!(reply.is_empty() || reply.starts_with("HTTP/1.0 400"), "{reply}");
        // The server still works afterwards.
        assert_eq!(get(srv.addr(), "/healthz").unwrap().0, 200);
    }

    #[test]
    fn drop_stops_accepting() {
        let addr = {
            let srv = test_server();
            srv.addr()
        };
        assert!(get(addr, "/healthz").is_err());
    }
}
