//! Queues (§4.6): "they allow different portions of the graph to execute
//! asynchronously … and to hand off data through Enqueue and Dequeue
//! operations. Enqueue operations can block until space becomes available,
//! and Dequeue operations can block until a desired minimum number of
//! elements are available."
//!
//! Blocking is expressed continuation-style: the Enqueue/Dequeue *kernels*
//! are asynchronous (§5.3), so a blocked queue op parks a callback here
//! instead of tying up an executor thread.
//!
//! Two implementations, as in the paper: a FIFO queue and a
//! `RandomShuffleQueue` ("randomly shuffles its elements within a large
//! in-memory buffer").

use crate::error::{Result, Status};
use crate::tensor::Tensor;
use crate::util::rng::Pcg32;
use std::collections::VecDeque;
use std::sync::{Arc, Mutex};

/// An element is a tuple of tensors (one per queue component).
pub type Element = Vec<Tensor>;

pub type EnqueueDone = Box<dyn FnOnce(Result<()>) + Send>;
pub type DequeueDone = Box<dyn FnOnce(Result<Element>) + Send>;

/// Shared handle to a queue resource.
pub type QueueRef = Arc<dyn TensorQueue>;

pub trait TensorQueue: Send + Sync {
    fn enqueue_async(&self, element: Element, done: EnqueueDone);
    fn dequeue_async(&self, done: DequeueDone);
    /// Close the queue: pending and future enqueues fail; dequeues drain
    /// the remaining elements then fail with `OutOfRange` (TF semantics).
    fn close(&self, cancel_pending: bool);
    fn size(&self) -> usize;
    fn is_closed(&self) -> bool;
    fn num_components(&self) -> usize;
}

enum Discipline {
    Fifo,
    /// min_after_dequeue + PRNG (§4.6 shuffling queue).
    Shuffle { min_after_dequeue: usize, rng: Pcg32 },
}

struct State {
    buf: VecDeque<Element>,
    closed: bool,
    discipline: Discipline,
    pending_enqueues: VecDeque<(Element, EnqueueDone)>,
    pending_dequeues: VecDeque<DequeueDone>,
}

pub struct QueueImpl {
    capacity: usize,
    components: usize,
    state: Mutex<State>,
}

impl QueueImpl {
    pub fn fifo(capacity: usize, components: usize) -> QueueRef {
        Arc::new(QueueImpl {
            capacity: capacity.max(1),
            components,
            state: Mutex::new(State {
                buf: VecDeque::new(),
                closed: false,
                discipline: Discipline::Fifo,
                pending_enqueues: VecDeque::new(),
                pending_dequeues: VecDeque::new(),
            }),
        })
    }

    pub fn shuffle(
        capacity: usize,
        components: usize,
        min_after_dequeue: usize,
        seed: u64,
    ) -> QueueRef {
        Arc::new(QueueImpl {
            capacity: capacity.max(1),
            components,
            state: Mutex::new(State {
                buf: VecDeque::new(),
                closed: false,
                discipline: Discipline::Shuffle { min_after_dequeue, rng: Pcg32::new(seed) },
                pending_enqueues: VecDeque::new(),
                pending_dequeues: VecDeque::new(),
            }),
        })
    }

    /// Pop one element according to the discipline. Caller holds the lock
    /// and has checked availability.
    fn pop(state: &mut State) -> Element {
        match &mut state.discipline {
            Discipline::Fifo => state.buf.pop_front().expect("checked non-empty"),
            Discipline::Shuffle { rng, .. } => {
                let i = rng.index(state.buf.len());
                state.buf.swap_remove_back(i).expect("checked non-empty")
            }
        }
    }

    /// Can a dequeue proceed right now?
    fn dequeue_ready(state: &State) -> bool {
        if state.buf.is_empty() {
            return false;
        }
        match &state.discipline {
            Discipline::Fifo => true,
            Discipline::Shuffle { min_after_dequeue, .. } => {
                // Keep the buffer above the shuffle threshold unless closed
                // (after close we drain everything).
                state.closed || state.buf.len() > *min_after_dequeue
            }
        }
    }

    /// Fire any work that can now proceed. Callbacks run outside the lock.
    fn pump(&self) {
        let mut fired: Vec<Box<dyn FnOnce() + Send>> = Vec::new();
        {
            let mut s = self.state.lock().unwrap();
            loop {
                let mut progressed = false;
                // Admit pending enqueues while there is space.
                while s.buf.len() < self.capacity {
                    match s.pending_enqueues.pop_front() {
                        Some((el, done)) => {
                            s.buf.push_back(el);
                            fired.push(Box::new(move || done(Ok(()))));
                            progressed = true;
                        }
                        None => break,
                    }
                }
                // Serve pending dequeues while elements are available.
                while !s.pending_dequeues.is_empty() && Self::dequeue_ready(&s) {
                    let done = s.pending_dequeues.pop_front().unwrap();
                    let el = Self::pop(&mut s);
                    fired.push(Box::new(move || done(Ok(el))));
                    progressed = true;
                }
                // Closed and drained: fail the rest.
                if s.closed && s.buf.is_empty() {
                    while let Some(done) = s.pending_dequeues.pop_front() {
                        fired.push(Box::new(move || {
                            done(Err(Status::out_of_range("queue is closed and empty")))
                        }));
                        progressed = true;
                    }
                }
                if !progressed {
                    break;
                }
            }
        }
        for f in fired {
            f();
        }
    }
}

impl TensorQueue for QueueImpl {
    fn enqueue_async(&self, element: Element, done: EnqueueDone) {
        if element.len() != self.components {
            done(Err(Status::invalid_argument(format!(
                "enqueue of {}-component element into {}-component queue",
                element.len(),
                self.components
            ))));
            return;
        }
        {
            let mut s = self.state.lock().unwrap();
            if s.closed {
                drop(s);
                done(Err(Status::aborted("enqueue on closed queue")));
                return;
            }
            s.pending_enqueues.push_back((element, done));
        }
        self.pump();
    }

    fn dequeue_async(&self, done: DequeueDone) {
        {
            let mut s = self.state.lock().unwrap();
            s.pending_dequeues.push_back(done);
        }
        self.pump();
    }

    fn close(&self, cancel_pending: bool) {
        let mut cancelled: Vec<Box<dyn FnOnce() + Send>> = Vec::new();
        {
            let mut s = self.state.lock().unwrap();
            s.closed = true;
            // Pending enqueues always fail on close.
            while let Some((_, done)) = s.pending_enqueues.pop_front() {
                cancelled.push(Box::new(move || done(Err(Status::aborted("queue closed")))));
            }
            if cancel_pending {
                s.buf.clear();
            }
        }
        for f in cancelled {
            f();
        }
        self.pump();
    }

    fn size(&self) -> usize {
        self.state.lock().unwrap().buf.len()
    }

    fn is_closed(&self) -> bool {
        self.state.lock().unwrap().closed
    }

    fn num_components(&self) -> usize {
        self.components
    }
}

// ---- synchronous convenience wrappers (tests, input pipelines) -----------

/// Blocking enqueue (convenience for host code; kernels use the async API).
pub fn enqueue_blocking(q: &QueueRef, element: Element) -> Result<()> {
    let (tx, rx) = std::sync::mpsc::channel();
    q.enqueue_async(element, Box::new(move |r| {
        let _ = tx.send(r);
    }));
    rx.recv().map_err(|_| Status::internal("queue dropped callback"))?
}

/// Blocking dequeue.
pub fn dequeue_blocking(q: &QueueRef) -> Result<Element> {
    let (tx, rx) = std::sync::mpsc::channel();
    q.dequeue_async(Box::new(move |r| {
        let _ = tx.send(r);
    }));
    rx.recv().map_err(|_| Status::internal("queue dropped callback"))?
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::{AtomicUsize, Ordering};

    fn elem(v: f32) -> Element {
        vec![Tensor::scalar_f32(v)]
    }

    #[test]
    fn fifo_order() {
        let q = QueueImpl::fifo(10, 1);
        for i in 0..5 {
            enqueue_blocking(&q, elem(i as f32)).unwrap();
        }
        for i in 0..5 {
            let e = dequeue_blocking(&q).unwrap();
            assert_eq!(e[0].scalar_value_f32().unwrap(), i as f32);
        }
    }

    #[test]
    fn enqueue_blocks_when_full() {
        let q = QueueImpl::fifo(2, 1);
        enqueue_blocking(&q, elem(0.0)).unwrap();
        enqueue_blocking(&q, elem(1.0)).unwrap();
        let fired = Arc::new(AtomicUsize::new(0));
        let f2 = Arc::clone(&fired);
        q.enqueue_async(
            elem(2.0),
            Box::new(move |r| {
                r.unwrap();
                f2.fetch_add(1, Ordering::SeqCst);
            }),
        );
        // Still parked.
        assert_eq!(fired.load(Ordering::SeqCst), 0);
        assert_eq!(q.size(), 2);
        // Dequeue frees a slot; the parked enqueue completes.
        dequeue_blocking(&q).unwrap();
        assert_eq!(fired.load(Ordering::SeqCst), 1);
        assert_eq!(q.size(), 2);
    }

    #[test]
    fn dequeue_blocks_until_data() {
        let q = QueueImpl::fifo(4, 1);
        let fired = Arc::new(AtomicUsize::new(0));
        let f2 = Arc::clone(&fired);
        q.dequeue_async(Box::new(move |r| {
            assert_eq!(r.unwrap()[0].scalar_value_f32().unwrap(), 7.0);
            f2.fetch_add(1, Ordering::SeqCst);
        }));
        assert_eq!(fired.load(Ordering::SeqCst), 0);
        enqueue_blocking(&q, elem(7.0)).unwrap();
        assert_eq!(fired.load(Ordering::SeqCst), 1);
    }

    #[test]
    fn close_fails_pending_dequeues_after_drain() {
        let q = QueueImpl::fifo(4, 1);
        enqueue_blocking(&q, elem(1.0)).unwrap();
        q.close(false);
        // One element drains fine…
        assert!(dequeue_blocking(&q).is_ok());
        // …then OutOfRange, like TF.
        let e = dequeue_blocking(&q).unwrap_err();
        assert_eq!(e.code, crate::error::Code::OutOfRange);
        // Enqueue after close aborts.
        let e = enqueue_blocking(&q, elem(2.0)).unwrap_err();
        assert_eq!(e.code, crate::error::Code::Aborted);
    }

    #[test]
    fn shuffle_queue_randomizes() {
        let q = QueueImpl::shuffle(200, 1, 0, 42);
        for i in 0..100 {
            enqueue_blocking(&q, elem(i as f32)).unwrap();
        }
        q.close(false);
        let mut out = Vec::new();
        for _ in 0..100 {
            out.push(dequeue_blocking(&q).unwrap()[0].scalar_value_f32().unwrap());
        }
        let sorted: Vec<f32> = (0..100).map(|i| i as f32).collect();
        assert_ne!(out, sorted, "shuffle queue returned FIFO order");
        let mut copy = out.clone();
        copy.sort_by(|a, b| a.partial_cmp(b).unwrap());
        assert_eq!(copy, sorted, "shuffle queue lost/duplicated elements");
    }

    #[test]
    fn shuffle_respects_min_after_dequeue() {
        let q = QueueImpl::shuffle(100, 1, 5, 1);
        for i in 0..6 {
            enqueue_blocking(&q, elem(i as f32)).unwrap();
        }
        // 6 elements, min_after=5: exactly one dequeue can proceed.
        assert!(dequeue_blocking(&q).is_ok());
        let fired = Arc::new(AtomicUsize::new(0));
        let f2 = Arc::clone(&fired);
        q.dequeue_async(Box::new(move |_| {
            f2.fetch_add(1, Ordering::SeqCst);
        }));
        assert_eq!(fired.load(Ordering::SeqCst), 0, "dequeue should park below threshold");
        enqueue_blocking(&q, elem(9.0)).unwrap(); // back above threshold
        assert_eq!(fired.load(Ordering::SeqCst), 1);
    }

    #[test]
    fn component_count_checked() {
        let q = QueueImpl::fifo(4, 2);
        let e = enqueue_blocking(&q, elem(1.0)).unwrap_err();
        assert_eq!(e.code, crate::error::Code::InvalidArgument);
    }

    #[test]
    fn producer_consumer_threads() {
        let q = QueueImpl::fifo(8, 1);
        let q2 = q.clone();
        let producer = std::thread::spawn(move || {
            for i in 0..500 {
                enqueue_blocking(&q2, elem(i as f32)).unwrap();
            }
        });
        let mut sum = 0.0;
        for _ in 0..500 {
            sum += dequeue_blocking(&q).unwrap()[0].scalar_value_f32().unwrap();
        }
        producer.join().unwrap();
        assert_eq!(sum, (0..500).sum::<i32>() as f32);
    }
}
