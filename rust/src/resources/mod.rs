//! Long-lived mutable state: Variables live in *Containers* (§4.7 — "the
//! backing store for a Variable lives in a container. The default
//! container is one that persists until the process terminates … a
//! container can be reset by clearing it of its contents entirely"), and
//! so do queues and mutexes (§4.6, Table 1 row 7).
//!
//! One `ResourceMgr` exists per worker process; it is shared by every
//! Session/step, which is what lets "completely disjoint computation
//! graphs associated with different Sessions" share state (§4.7).

use crate::error::{Result, Status};
use crate::queue::QueueRef;
use crate::tensor::Tensor;
use std::collections::HashMap;
use std::sync::{Arc, Condvar, Mutex, RwLock};

/// The mutable storage behind one Variable node.
#[derive(Debug, Default)]
pub struct VariableState {
    value: Mutex<Option<Tensor>>,
}

impl VariableState {
    /// Read the current value; `FailedPrecondition` when uninitialized
    /// (TF's "attempting to use uninitialized value" error).
    pub fn read(&self, name: &str) -> Result<Tensor> {
        self.value
            .lock()
            .unwrap()
            .clone()
            .ok_or_else(|| {
                Status::failed_precondition(format!("attempting to use uninitialized variable {name:?}"))
            })
    }

    pub fn is_initialized(&self) -> bool {
        self.value.lock().unwrap().is_some()
    }

    pub fn assign(&self, t: Tensor) {
        *self.value.lock().unwrap() = Some(t);
    }

    /// value += delta (the paper's AssignAdd, "equivalent to +=").
    pub fn assign_add(&self, name: &str, delta: &Tensor) -> Result<Tensor> {
        let mut guard = self.value.lock().unwrap();
        let cur = guard.as_ref().ok_or_else(|| {
            Status::failed_precondition(format!("AssignAdd on uninitialized variable {name:?}"))
        })?;
        let new = crate::kernels::math::binary_elementwise(cur, delta, "Add")?;
        *guard = Some(new.clone());
        Ok(new)
    }

    pub fn assign_sub(&self, name: &str, delta: &Tensor) -> Result<Tensor> {
        let mut guard = self.value.lock().unwrap();
        let cur = guard.as_ref().ok_or_else(|| {
            Status::failed_precondition(format!("AssignSub on uninitialized variable {name:?}"))
        })?;
        let new = crate::kernels::math::binary_elementwise(cur, delta, "Sub")?;
        *guard = Some(new.clone());
        Ok(new)
    }

    /// Run `f` over the variable under its lock (optimizer apply ops need
    /// read-modify-write atomicity; §6 lesson 4 is about exactly the bugs
    /// you get without this).
    pub fn update(&self, name: &str, f: impl FnOnce(&Tensor) -> Result<Tensor>) -> Result<Tensor> {
        let mut guard = self.value.lock().unwrap();
        let cur = guard.as_ref().ok_or_else(|| {
            Status::failed_precondition(format!("update of uninitialized variable {name:?}"))
        })?;
        let new = f(cur)?;
        *guard = Some(new.clone());
        Ok(new)
    }

    /// Like `update` but initializes from `init` when empty (Adam/Adagrad
    /// slot variables).
    pub fn update_or_init(
        &self,
        init: impl FnOnce() -> Result<Tensor>,
        f: impl FnOnce(&Tensor) -> Result<Tensor>,
    ) -> Result<Tensor> {
        let mut guard = self.value.lock().unwrap();
        let cur = match guard.as_ref() {
            Some(t) => t.clone(),
            None => init()?,
        };
        let new = f(&cur)?;
        *guard = Some(new.clone());
        Ok(new)
    }
}

/// A simple cooperative mutex resource (Table 1: MutexAcquire/MutexRelease).
#[derive(Debug, Default)]
pub struct MutexState {
    locked: Mutex<bool>,
    cond: Condvar,
}

impl MutexState {
    pub fn acquire(&self) {
        let mut locked = self.locked.lock().unwrap();
        while *locked {
            locked = self.cond.wait(locked).unwrap();
        }
        *locked = true;
    }

    pub fn try_acquire(&self) -> bool {
        let mut locked = self.locked.lock().unwrap();
        if *locked {
            false
        } else {
            *locked = true;
            true
        }
    }

    pub fn release(&self) -> Result<()> {
        let mut locked = self.locked.lock().unwrap();
        if !*locked {
            return Err(Status::failed_precondition("MutexRelease of unheld mutex"));
        }
        *locked = false;
        self.cond.notify_one();
        Ok(())
    }
}

/// One named container of resources (§4.7).
#[derive(Default)]
pub struct Container {
    vars: RwLock<HashMap<String, Arc<VariableState>>>,
    queues: RwLock<HashMap<String, QueueRef>>,
    mutexes: RwLock<HashMap<String, Arc<MutexState>>>,
}

impl Container {
    /// Get-or-create the variable slot named `name`.
    pub fn variable(&self, name: &str) -> Arc<VariableState> {
        if let Some(v) = self.vars.read().unwrap().get(name) {
            return Arc::clone(v);
        }
        let mut w = self.vars.write().unwrap();
        Arc::clone(w.entry(name.to_string()).or_default())
    }

    pub fn lookup_variable(&self, name: &str) -> Option<Arc<VariableState>> {
        self.vars.read().unwrap().get(name).cloned()
    }

    pub fn variable_names(&self) -> Vec<String> {
        let mut names: Vec<String> = self.vars.read().unwrap().keys().cloned().collect();
        names.sort();
        names
    }

    /// Get-or-create a queue; `make` runs only on first touch.
    pub fn queue_or_create(&self, name: &str, make: impl FnOnce() -> QueueRef) -> QueueRef {
        if let Some(q) = self.queues.read().unwrap().get(name) {
            return q.clone();
        }
        let mut w = self.queues.write().unwrap();
        w.entry(name.to_string()).or_insert_with(make).clone()
    }

    pub fn lookup_queue(&self, name: &str) -> Result<QueueRef> {
        self.queues
            .read()
            .unwrap()
            .get(name)
            .cloned()
            .ok_or_else(|| Status::not_found(format!("queue {name:?} not found (did its queue op run?)")))
    }

    pub fn mutex(&self, name: &str) -> Arc<MutexState> {
        if let Some(m) = self.mutexes.read().unwrap().get(name) {
            return Arc::clone(m);
        }
        let mut w = self.mutexes.write().unwrap();
        Arc::clone(w.entry(name.to_string()).or_default())
    }

    /// §4.7: reset = clear contents entirely.
    pub fn reset(&self) {
        self.vars.write().unwrap().clear();
        self.queues.write().unwrap().clear();
        self.mutexes.write().unwrap().clear();
    }
}

/// All containers of one worker process. The default container is "".
#[derive(Default)]
pub struct ResourceMgr {
    containers: RwLock<HashMap<String, Arc<Container>>>,
}

impl ResourceMgr {
    pub fn new() -> Arc<ResourceMgr> {
        Arc::new(ResourceMgr::default())
    }

    pub fn container(&self, name: &str) -> Arc<Container> {
        if let Some(c) = self.containers.read().unwrap().get(name) {
            return Arc::clone(c);
        }
        let mut w = self.containers.write().unwrap();
        Arc::clone(w.entry(name.to_string()).or_default())
    }

    pub fn default_container(&self) -> Arc<Container> {
        self.container("")
    }

    pub fn reset_container(&self, name: &str) {
        if let Some(c) = self.containers.read().unwrap().get(name) {
            c.reset();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tensor::Tensor;

    #[test]
    fn uninitialized_variable_errors() {
        let c = Container::default();
        let v = c.variable("w");
        assert!(!v.is_initialized());
        let e = v.read("w").unwrap_err();
        assert_eq!(e.code, crate::error::Code::FailedPrecondition);
    }

    #[test]
    fn assign_then_read() {
        let c = Container::default();
        let v = c.variable("w");
        v.assign(Tensor::scalar_f32(3.0));
        assert_eq!(v.read("w").unwrap().scalar_value_f32().unwrap(), 3.0);
        // Same slot returned for same name.
        let v2 = c.variable("w");
        assert_eq!(v2.read("w").unwrap().scalar_value_f32().unwrap(), 3.0);
    }

    #[test]
    fn containers_isolate_and_reset() {
        let mgr = ResourceMgr::new();
        mgr.container("a").variable("x").assign(Tensor::scalar_f32(1.0));
        mgr.container("b").variable("x").assign(Tensor::scalar_f32(2.0));
        assert_eq!(
            mgr.container("a").variable("x").read("x").unwrap().scalar_value_f32().unwrap(),
            1.0
        );
        mgr.reset_container("a");
        assert!(!mgr.container("a").variable("x").is_initialized());
        // Container b untouched.
        assert!(mgr.container("b").variable("x").is_initialized());
    }

    #[test]
    fn mutex_acquire_release() {
        let c = Container::default();
        let m = c.mutex("mu");
        assert!(m.try_acquire());
        assert!(!m.try_acquire());
        m.release().unwrap();
        assert!(m.try_acquire());
        m.release().unwrap();
        assert!(m.release().is_err());
    }

    #[test]
    fn mutex_blocks_across_threads() {
        let c = Arc::new(Container::default());
        let m = c.mutex("mu");
        m.acquire();
        let m2 = Arc::clone(&m);
        let h = std::thread::spawn(move || {
            m2.acquire(); // blocks until main releases
            m2.release().unwrap();
        });
        std::thread::sleep(std::time::Duration::from_millis(20));
        m.release().unwrap();
        h.join().unwrap();
    }

    #[test]
    fn variable_names_sorted() {
        let c = Container::default();
        c.variable("b").assign(Tensor::scalar_f32(0.0));
        c.variable("a").assign(Tensor::scalar_f32(0.0));
        assert_eq!(c.variable_names(), vec!["a".to_string(), "b".to_string()]);
    }
}
