//! Shared wire format: length-prefixed frames plus the little-endian
//! primitive/status/tensor-map codecs used by every TCP protocol in the
//! crate.
//!
//! Originally these lived inside `distributed::proto` (§3.3 master ⇄
//! worker traffic). The serving front end (`crate::serving::net`) speaks
//! the same framing on a different port with its own message-type space,
//! so the transport layer moved here: one frame layout, two protocols.
//!
//! Frame layout (unchanged from the original `distributed::proto`):
//!
//! ```text
//! u32 length (payload bytes + 1, little-endian) | u8 msg_type | payload
//! ```
//!
//! The codec helpers are deliberately defensive: every read is
//! bounds-checked so a malformed or truncated frame from a misbehaving
//! peer produces `InvalidArgument`, never a panic — the serving front end
//! accepts connections from arbitrary clients.

use crate::error::{Code, Result, Status};
use crate::obs::{Counter, MetricsRegistry};
use crate::tensor::{codec, Tensor};
use crate::util::byteorder::LittleEndian;
use std::io::{Read, Write};
use std::net::TcpStream;
use std::sync::{Arc, OnceLock};

/// Upper bound on a single frame (1 GiB). Large enough for any tensor
/// this runtime ships; small enough that a corrupt length prefix cannot
/// drive an unbounded allocation.
pub const MAX_FRAME_BYTES: usize = 1 << 30;

/// Write one frame: u32 length, u8 type, payload. Generic over the sink
/// so tests can frame into an in-memory cursor. Oversize payloads are
/// rejected *before* any bytes hit the stream: a wrapped u32 length (≥
/// 4 GiB) or a frame the peer's [`read_frame`] would refuse mid-stream
/// must fail as a status here, not desync the connection there.
pub fn write_frame<S: Write>(stream: &mut S, msg_type: u8, payload: &[u8]) -> Result<()> {
    if payload.len() >= MAX_FRAME_BYTES {
        return Err(Status::invalid_argument(format!(
            "frame payload of {} bytes exceeds the {MAX_FRAME_BYTES}-byte limit",
            payload.len()
        )));
    }
    let mut header = [0u8; 5];
    LittleEndian::write_u32(&mut header, payload.len() as u32 + 1);
    header[4] = msg_type;
    stream.write_all(&header)?;
    stream.write_all(payload)?;
    stream.flush()?;
    Ok(())
}

/// Read one frame; the inverse of [`write_frame`].
pub fn read_frame<S: Read>(stream: &mut S) -> Result<(u8, Vec<u8>)> {
    let mut header = [0u8; 5];
    stream.read_exact(&mut header)?;
    let len = LittleEndian::read_u32(&header) as usize;
    if len == 0 {
        return Err(Status::unavailable("empty frame"));
    }
    if len > MAX_FRAME_BYTES {
        return Err(Status::invalid_argument(format!(
            "frame of {len} bytes exceeds the {MAX_FRAME_BYTES}-byte limit"
        )));
    }
    let msg_type = header[4];
    let mut payload = vec![0u8; len - 1];
    stream.read_exact(&mut payload)?;
    Ok((msg_type, payload))
}

/// One-shot RPC helper: connect, send one frame, await one reply frame.
pub fn rpc(addr: &str, msg_type: u8, payload: &[u8]) -> Result<(u8, Vec<u8>)> {
    let mut stream = TcpStream::connect(addr)
        .map_err(|e| Status::unavailable(format!("connect {addr}: {e}")))?;
    stream.set_nodelay(true).ok();
    write_frame(&mut stream, msg_type, payload)?;
    read_frame(&mut stream)
}

// ---- wire-level metrics ----------------------------------------------------

/// Per-message-type frame/byte counters for both directions.
struct TypeCounters {
    frames_in: Arc<Counter>,
    bytes_in: Arc<Counter>,
    frames_out: Arc<Counter>,
    bytes_out: Arc<Counter>,
}

/// Frame/byte accounting for one endpoint, registered in a
/// [`MetricsRegistry`] under `{prefix}/{msg_name}/{frames,bytes}_{in,out}`
/// (plus `{prefix}/bytes_{in,out}_total` rollups). The hot path is
/// lock-free: per-type counter handles are created once (`OnceLock`) and
/// every subsequent frame is four relaxed atomic adds. Byte counts
/// include the 5-byte frame header, so they match what the socket saw.
///
/// Servers wrap their streams with [`WireMetrics::read_frame`] /
/// [`WireMetrics::write_frame`] instead of the free functions; the
/// protocol passes a `namer` so counters carry message names
/// (`wire/PS_PUSH/bytes_in`) rather than raw type bytes.
pub struct WireMetrics {
    registry: Arc<MetricsRegistry>,
    prefix: String,
    namer: fn(u8) -> String,
    bytes_in_total: Arc<Counter>,
    bytes_out_total: Arc<Counter>,
    per_type: [OnceLock<TypeCounters>; 256],
}

/// Fallback message namer: the raw type byte.
pub fn raw_msg_name(t: u8) -> String {
    format!("MSG_{t}")
}

impl WireMetrics {
    pub fn new(
        registry: &Arc<MetricsRegistry>,
        prefix: &str,
        namer: fn(u8) -> String,
    ) -> Arc<WireMetrics> {
        Arc::new(WireMetrics {
            registry: Arc::clone(registry),
            prefix: prefix.to_string(),
            namer,
            bytes_in_total: registry.counter(&format!("{prefix}/bytes_in_total")),
            bytes_out_total: registry.counter(&format!("{prefix}/bytes_out_total")),
            per_type: std::array::from_fn(|_| OnceLock::new()),
        })
    }

    fn counters(&self, msg_type: u8) -> &TypeCounters {
        self.per_type[msg_type as usize].get_or_init(|| {
            let name = (self.namer)(msg_type);
            let path = format!("{}/{name}", self.prefix);
            TypeCounters {
                frames_in: self.registry.counter(&format!("{path}/frames_in")),
                bytes_in: self.registry.counter(&format!("{path}/bytes_in")),
                frames_out: self.registry.counter(&format!("{path}/frames_out")),
                bytes_out: self.registry.counter(&format!("{path}/bytes_out")),
            }
        })
    }

    /// Account one received frame of `payload_len` payload bytes.
    pub fn note_in(&self, msg_type: u8, payload_len: usize) {
        let c = self.counters(msg_type);
        c.frames_in.inc();
        c.bytes_in.add(payload_len as u64 + 5);
        self.bytes_in_total.add(payload_len as u64 + 5);
    }

    /// Account one sent frame of `payload_len` payload bytes.
    pub fn note_out(&self, msg_type: u8, payload_len: usize) {
        let c = self.counters(msg_type);
        c.frames_out.inc();
        c.bytes_out.add(payload_len as u64 + 5);
        self.bytes_out_total.add(payload_len as u64 + 5);
    }

    /// [`read_frame`] with accounting.
    pub fn read_frame<S: Read>(&self, stream: &mut S) -> Result<(u8, Vec<u8>)> {
        let (msg_type, payload) = read_frame(stream)?;
        self.note_in(msg_type, payload.len());
        Ok((msg_type, payload))
    }

    /// [`write_frame`] with accounting (only successful writes count).
    pub fn write_frame<S: Write>(
        &self,
        stream: &mut S,
        msg_type: u8,
        payload: &[u8],
    ) -> Result<()> {
        write_frame(stream, msg_type, payload)?;
        self.note_out(msg_type, payload.len());
        Ok(())
    }

    /// Total bytes seen in both directions (header included).
    pub fn total_bytes(&self) -> u64 {
        self.bytes_in_total.get() + self.bytes_out_total.get()
    }

    pub fn bytes_in(&self) -> u64 {
        self.bytes_in_total.get()
    }

    pub fn bytes_out(&self) -> u64 {
        self.bytes_out_total.get()
    }
}

// ---- primitive payload codecs ----------------------------------------------

pub fn put_u8(out: &mut Vec<u8>, v: u8) {
    out.push(v);
}

pub fn get_u8(buf: &[u8], pos: &mut usize) -> Result<u8> {
    if buf.len() <= *pos {
        return Err(Status::invalid_argument("truncated payload (u8)"));
    }
    let v = buf[*pos];
    *pos += 1;
    Ok(v)
}

pub fn put_u32(out: &mut Vec<u8>, v: u32) {
    let mut b = [0u8; 4];
    LittleEndian::write_u32(&mut b, v);
    out.extend_from_slice(&b);
}

pub fn put_u64(out: &mut Vec<u8>, v: u64) {
    let mut b = [0u8; 8];
    LittleEndian::write_u64(&mut b, v);
    out.extend_from_slice(&b);
}

pub fn put_str(out: &mut Vec<u8>, s: &str) {
    put_u32(out, s.len() as u32);
    out.extend_from_slice(s.as_bytes());
}

pub fn get_u32(buf: &[u8], pos: &mut usize) -> Result<u32> {
    if buf.len() < *pos + 4 {
        return Err(Status::invalid_argument("truncated payload (u32)"));
    }
    let v = LittleEndian::read_u32(&buf[*pos..]);
    *pos += 4;
    Ok(v)
}

pub fn get_u64(buf: &[u8], pos: &mut usize) -> Result<u64> {
    if buf.len() < *pos + 8 {
        return Err(Status::invalid_argument("truncated payload (u64)"));
    }
    let v = LittleEndian::read_u64(&buf[*pos..]);
    *pos += 8;
    Ok(v)
}

pub fn get_str(buf: &[u8], pos: &mut usize) -> Result<String> {
    let len = get_u32(buf, pos)? as usize;
    if buf.len() < *pos + len {
        return Err(Status::invalid_argument("truncated payload (string)"));
    }
    let s = String::from_utf8_lossy(&buf[*pos..*pos + len]).to_string();
    *pos += len;
    Ok(s)
}

// ---- status ----------------------------------------------------------------

/// One byte 255 for OK, else the `Code` byte followed by a
/// length-prefixed message.
pub fn encode_status(out: &mut Vec<u8>, s: &Result<()>) {
    match s {
        Ok(()) => out.push(255),
        Err(e) => {
            out.push(e.code.as_u8());
            put_str(out, &e.message);
        }
    }
}

pub fn decode_status(buf: &[u8], pos: &mut usize) -> Result<Result<()>> {
    if buf.len() <= *pos {
        return Err(Status::invalid_argument("truncated payload (status)"));
    }
    let code = buf[*pos];
    *pos += 1;
    if code == 255 {
        return Ok(Ok(()));
    }
    let msg = get_str(buf, pos)?;
    Ok(Err(Status::new(Code::from_u8(code), msg)))
}

// ---- string lists ----------------------------------------------------------

pub fn encode_str_list(out: &mut Vec<u8>, names: &[String]) {
    put_u32(out, names.len() as u32);
    for n in names {
        put_str(out, n);
    }
}

pub fn decode_str_list(buf: &[u8], pos: &mut usize) -> Result<Vec<String>> {
    let n = get_u32(buf, pos)? as usize;
    let mut out = Vec::with_capacity(n.min(4096));
    for _ in 0..n {
        out.push(get_str(buf, pos)?);
    }
    Ok(out)
}

// ---- single tensors --------------------------------------------------------

/// One u64-length-prefixed `tensor::codec` payload (the same per-entry
/// layout `encode_tensor_map` uses, reusable for messages that carry
/// tensors outside a map).
pub fn put_tensor(out: &mut Vec<u8>, t: &Tensor) {
    let payload = codec::encode(t);
    put_u64(out, payload.len() as u64);
    out.extend_from_slice(&payload);
}

pub fn get_tensor(buf: &[u8], pos: &mut usize) -> Result<Tensor> {
    // Compare in u64 against the remaining bytes: `*pos + plen` on an
    // attacker-controlled u64 length would wrap (and `as usize` truncates
    // on 32-bit), bypassing the bounds check.
    let plen64 = get_u64(buf, pos)?;
    if plen64 > (buf.len() - *pos) as u64 {
        return Err(Status::invalid_argument("truncated payload (tensor)"));
    }
    let plen = plen64 as usize;
    let (t, used) = codec::decode(&buf[*pos..*pos + plen])?;
    if used != plen {
        return Err(Status::invalid_argument("tensor payload mismatch"));
    }
    *pos += plen;
    Ok(t)
}

// ---- tensor maps -----------------------------------------------------------

/// Named-tensor map: u32 count, then per entry a length-prefixed name and
/// a u64-length-prefixed `tensor::codec` payload.
pub fn encode_tensor_map(out: &mut Vec<u8>, m: &[(String, Tensor)]) {
    put_u32(out, m.len() as u32);
    for (k, t) in m {
        put_str(out, k);
        put_tensor(out, t);
    }
}

pub fn decode_tensor_map(buf: &[u8], pos: &mut usize) -> Result<Vec<(String, Tensor)>> {
    let n = get_u32(buf, pos)? as usize;
    let mut out = Vec::with_capacity(n.min(4096));
    for _ in 0..n {
        let key = get_str(buf, pos)?;
        let t = get_tensor(buf, pos)?;
        out.push((key, t));
    }
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::io::Cursor;

    #[test]
    fn frames_roundtrip_in_memory() {
        let mut buf = Cursor::new(Vec::new());
        write_frame(&mut buf, 7, b"hello").unwrap();
        write_frame(&mut buf, 9, b"").unwrap();
        buf.set_position(0);
        let (t, p) = read_frame(&mut buf).unwrap();
        assert_eq!((t, p.as_slice()), (7, b"hello".as_slice()));
        let (t, p) = read_frame(&mut buf).unwrap();
        assert_eq!((t, p.len()), (9, 0));
    }

    #[test]
    fn oversized_frame_rejected_without_allocation() {
        let mut header = [0u8; 5];
        LittleEndian::write_u32(&mut header, u32::MAX);
        header[4] = 1;
        let mut cur = Cursor::new(header.to_vec());
        let e = read_frame(&mut cur).unwrap_err();
        assert_eq!(e.code, Code::InvalidArgument);
    }

    #[test]
    fn primitives_roundtrip() {
        let mut out = Vec::new();
        put_u8(&mut out, 200);
        put_u32(&mut out, 42);
        put_u64(&mut out, u64::MAX - 1);
        put_str(&mut out, "model/v1");
        let mut pos = 0;
        assert_eq!(get_u8(&out, &mut pos).unwrap(), 200);
        assert_eq!(get_u32(&out, &mut pos).unwrap(), 42);
        assert_eq!(get_u64(&out, &mut pos).unwrap(), u64::MAX - 1);
        assert_eq!(get_str(&out, &mut pos).unwrap(), "model/v1");
        assert_eq!(pos, out.len());
        assert!(get_u8(&out, &mut pos).is_err());
    }

    #[test]
    fn truncated_payloads_error_not_panic() {
        let mut out = Vec::new();
        put_str(&mut out, "a long enough name");
        // Chop the payload mid-string: every prefix must decode to an
        // error rather than slicing out of bounds.
        for cut in 0..out.len() {
            let mut pos = 0;
            assert!(get_str(&out[..cut], &mut pos).is_err(), "cut at {cut}");
        }
    }

    #[test]
    fn status_roundtrip() {
        let mut out = Vec::new();
        encode_status(&mut out, &Ok(()));
        encode_status(&mut out, &Err(Status::not_found("no such model")));
        let mut pos = 0;
        assert!(decode_status(&out, &mut pos).unwrap().is_ok());
        let e = decode_status(&out, &mut pos).unwrap().unwrap_err();
        assert_eq!(e.code, Code::NotFound);
        assert_eq!(e.message, "no such model");
    }

    #[test]
    fn huge_declared_tensor_length_rejected_not_panicking() {
        // plen near u64::MAX must fail the bounds check, not wrap it.
        let mut out = Vec::new();
        put_u32(&mut out, 1); // one entry
        put_str(&mut out, "x");
        put_u64(&mut out, u64::MAX - 5);
        out.extend_from_slice(&[0u8; 16]);
        let mut pos = 0;
        assert!(decode_tensor_map(&out, &mut pos).is_err());
    }

    #[test]
    fn tensor_map_roundtrip() {
        let m = vec![
            ("x".to_string(), Tensor::from_f32(vec![2, 2], vec![1., 2., 3., 4.]).unwrap()),
            ("step".to_string(), Tensor::scalar_i64(9)),
        ];
        let mut out = Vec::new();
        encode_tensor_map(&mut out, &m);
        let mut pos = 0;
        let dec = decode_tensor_map(&out, &mut pos).unwrap();
        assert_eq!(pos, out.len());
        assert_eq!(dec.len(), 2);
        assert_eq!(dec[0].0, "x");
        assert_eq!(dec[0].1.as_f32().unwrap(), &[1., 2., 3., 4.]);
        assert_eq!(dec[1].1.scalar_value_i64().unwrap(), 9);
    }

    #[test]
    fn str_list_roundtrip() {
        let names = vec!["a:0".to_string(), "b/c:1".to_string()];
        let mut out = Vec::new();
        encode_str_list(&mut out, &names);
        let mut pos = 0;
        assert_eq!(decode_str_list(&out, &mut pos).unwrap(), names);
    }

    #[test]
    fn wire_metrics_count_frames_and_bytes_per_type() {
        let reg = MetricsRegistry::new();
        let m = WireMetrics::new(&reg, "wire", raw_msg_name);
        let mut buf = Cursor::new(Vec::new());
        m.write_frame(&mut buf, 7, b"hello").unwrap();
        m.write_frame(&mut buf, 9, b"").unwrap();
        buf.set_position(0);
        m.read_frame(&mut buf).unwrap();
        m.read_frame(&mut buf).unwrap();
        assert_eq!(reg.counter_value("wire/MSG_7/frames_out"), Some(1));
        assert_eq!(reg.counter_value("wire/MSG_7/bytes_out"), Some(10)); // 5 hdr + 5 payload
        assert_eq!(reg.counter_value("wire/MSG_9/bytes_in"), Some(5));
        assert_eq!(m.bytes_in(), m.bytes_out());
        assert_eq!(m.total_bytes(), 30);
        // The dump carries every wire counter.
        let dump = reg.export_json();
        assert!(dump.contains("\"wire/MSG_9/frames_in\":1"), "{dump}");
    }
}
