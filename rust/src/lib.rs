//! # RustFlow
//!
//! A reproduction of *TensorFlow: Large-Scale Machine Learning on
//! Heterogeneous Distributed Systems* (Abadi et al., 2015/2016 whitepaper)
//! as a rust dataflow-execution engine whose numeric hot paths are
//! AOT-compiled JAX/Pallas programs executed through PJRT.
//!
//! Layering (see DESIGN.md):
//! * L4 — [`serving`]: an inference front end over a Session — bounded
//!   admission, dynamic request batching, per-request handles; plus the
//!   model lifecycle manager ([`serving::ModelManager`]: versioned
//!   models, hot-swap, draining) and a TCP predict front end
//!   ([`serving::net`] over the shared [`wire`] framing).
//! * L3 — this crate: graphs, sessions, executors, placement, Send/Recv
//!   partitioning, distributed master/worker, queues, autodiff,
//!   checkpointing, optimizations, tooling.
//! * L2 — `python/compile/model.py`: JAX train-step lowered to HLO text.
//! * L1 — `python/compile/kernels/`: Pallas kernels inside the L2 program.
//! * Bridge — [`runtime`]: loads `artifacts/*.hlo.txt` and exposes them to
//!   graphs as the `XlaCall` op.

#![deny(rustdoc::broken_intra_doc_links)]

pub mod autodiff;
pub mod baseline;
pub mod checkpoint;
pub mod compress;
pub mod data;
pub mod device;
pub mod distributed;
pub mod error;
pub mod executor;
pub mod graph;
pub mod kernels;
pub mod memory;
pub mod obs;
pub mod ops;
pub mod optim;
pub mod partition;
pub mod passes;
pub mod placement;
pub mod models;
pub mod queue;
pub mod replicate;
pub mod runtime;
pub mod serving;
pub mod session;
pub mod sparse;
pub mod summary;
pub mod xla_model;
pub mod rendezvous;
pub mod resources;
pub mod tensor;
pub mod tracing_tools;
pub mod util;
pub mod wire;

pub use error::{Result, Status};
pub use graph::{Endpoint, Graph, NodeId};
pub use ops::builder::GraphBuilder;
pub use session::{Session, SessionOptions};
pub use tensor::{DType, Shape, Tensor};
