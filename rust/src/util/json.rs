//! Minimal JSON writer (no serde in the image).
//!
//! Used by the EEG-style tracer (chrome://tracing format) and the
//! TensorBoard-analog summary writer. Write-only: RustFlow never needs to
//! parse JSON.

use std::fmt::Write as _;

/// A JSON value under construction.
#[derive(Debug, Clone)]
pub enum Json {
    Null,
    Bool(bool),
    Int(i64),
    Float(f64),
    Str(String),
    Array(Vec<Json>),
    Object(Vec<(String, Json)>),
}

impl Json {
    pub fn obj() -> Json {
        Json::Object(Vec::new())
    }

    pub fn arr() -> Json {
        Json::Array(Vec::new())
    }

    /// Insert a field (object only; panics otherwise — programmer error).
    pub fn set(mut self, key: &str, value: impl Into<Json>) -> Json {
        match &mut self {
            Json::Object(fields) => fields.push((key.to_string(), value.into())),
            _ => panic!("Json::set on non-object"),
        }
        self
    }

    /// Push an element (array only).
    pub fn push(&mut self, value: impl Into<Json>) {
        match self {
            Json::Array(items) => items.push(value.into()),
            _ => panic!("Json::push on non-array"),
        }
    }

    pub fn render(&self) -> String {
        let mut out = String::new();
        self.render_into(&mut out);
        out
    }

    fn render_into(&self, out: &mut String) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Int(i) => {
                let _ = write!(out, "{i}");
            }
            Json::Float(f) => {
                if f.is_finite() {
                    let _ = write!(out, "{f}");
                } else {
                    // JSON has no Inf/NaN; emit null like most tools do.
                    out.push_str("null");
                }
            }
            Json::Str(s) => escape_into(s, out),
            Json::Array(items) => {
                out.push('[');
                for (i, item) in items.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    item.render_into(out);
                }
                out.push(']');
            }
            Json::Object(fields) => {
                out.push('{');
                for (i, (k, v)) in fields.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    escape_into(k, out);
                    out.push(':');
                    v.render_into(out);
                }
                out.push('}');
            }
        }
    }
}

fn escape_into(s: &str, out: &mut String) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => {
                let _ = write!(out, "\\u{:04x}", c as u32);
            }
            c => out.push(c),
        }
    }
    out.push('"');
}

impl From<bool> for Json {
    fn from(v: bool) -> Json {
        Json::Bool(v)
    }
}
impl From<i64> for Json {
    fn from(v: i64) -> Json {
        Json::Int(v)
    }
}
impl From<usize> for Json {
    fn from(v: usize) -> Json {
        Json::Int(v as i64)
    }
}
impl From<u64> for Json {
    fn from(v: u64) -> Json {
        Json::Int(v as i64)
    }
}
impl From<f64> for Json {
    fn from(v: f64) -> Json {
        Json::Float(v)
    }
}
impl From<f32> for Json {
    fn from(v: f32) -> Json {
        Json::Float(v as f64)
    }
}
impl From<&str> for Json {
    fn from(v: &str) -> Json {
        Json::Str(v.to_string())
    }
}
impl From<String> for Json {
    fn from(v: String) -> Json {
        Json::Str(v)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn renders_nested() {
        let mut arr = Json::arr();
        arr.push(1i64);
        arr.push("two");
        let j = Json::obj()
            .set("name", "MatMul")
            .set("dur", 12.5f64)
            .set("ok", true)
            .set("items", arr);
        assert_eq!(
            j.render(),
            r#"{"name":"MatMul","dur":12.5,"ok":true,"items":[1,"two"]}"#
        );
    }

    #[test]
    fn escapes_strings() {
        let j = Json::Str("a\"b\\c\nd".to_string());
        assert_eq!(j.render(), r#""a\"b\\c\nd""#);
    }

    #[test]
    fn non_finite_floats_become_null() {
        assert_eq!(Json::Float(f64::NAN).render(), "null");
        assert_eq!(Json::Float(f64::INFINITY).render(), "null");
    }
}
