//! Minimal JSON writer + parser (no serde in the image).
//!
//! Used by the EEG-style tracer (chrome://tracing format), the
//! TensorBoard-analog summary writer, and the metrics registry. The
//! parser ([`Json::parse`]) exists so trace and stats dumps can be read
//! back — by tests validating chrome-trace output and by
//! `StepStats::from_json` — and is defensive: malformed input returns
//! `Err`, never panics, and nesting depth is capped so hostile input
//! can't blow the stack.

use std::fmt::Write as _;

/// A JSON value under construction.
#[derive(Debug, Clone)]
pub enum Json {
    Null,
    Bool(bool),
    Int(i64),
    Float(f64),
    Str(String),
    Array(Vec<Json>),
    Object(Vec<(String, Json)>),
}

impl Json {
    pub fn obj() -> Json {
        Json::Object(Vec::new())
    }

    pub fn arr() -> Json {
        Json::Array(Vec::new())
    }

    /// Insert a field (object only; panics otherwise — programmer error).
    pub fn set(mut self, key: &str, value: impl Into<Json>) -> Json {
        match &mut self {
            Json::Object(fields) => fields.push((key.to_string(), value.into())),
            _ => panic!("Json::set on non-object"),
        }
        self
    }

    /// Push an element (array only).
    pub fn push(&mut self, value: impl Into<Json>) {
        match self {
            Json::Array(items) => items.push(value.into()),
            _ => panic!("Json::push on non-array"),
        }
    }

    pub fn render(&self) -> String {
        let mut out = String::new();
        self.render_into(&mut out);
        out
    }

    // ---- read-side accessors ----------------------------------------------

    /// Object field lookup (first match; `None` on non-objects).
    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Object(fields) => fields.iter().find(|(k, _)| k == key).map(|(_, v)| v),
            _ => None,
        }
    }

    pub fn as_array(&self) -> Option<&[Json]> {
        match self {
            Json::Array(items) => Some(items),
            _ => None,
        }
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_i64(&self) -> Option<i64> {
        match self {
            Json::Int(i) => Some(*i),
            _ => None,
        }
    }

    /// Numeric value as f64 (`Int` widens).
    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Int(i) => Some(*i as f64),
            Json::Float(f) => Some(*f),
            _ => None,
        }
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }

    /// Parse a JSON document. Strict enough for round-tripping our own
    /// output (and standard JSON generally); trailing non-whitespace is
    /// an error.
    pub fn parse(s: &str) -> Result<Json, String> {
        let mut p = Parser { b: s.as_bytes(), i: 0 };
        let v = p.value(0)?;
        p.skip_ws();
        if p.i != p.b.len() {
            return Err(format!("trailing bytes at offset {}", p.i));
        }
        Ok(v)
    }

    fn render_into(&self, out: &mut String) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Int(i) => {
                let _ = write!(out, "{i}");
            }
            Json::Float(f) => {
                if f.is_finite() {
                    let _ = write!(out, "{f}");
                } else {
                    // JSON has no Inf/NaN; emit null like most tools do.
                    out.push_str("null");
                }
            }
            Json::Str(s) => escape_into(s, out),
            Json::Array(items) => {
                out.push('[');
                for (i, item) in items.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    item.render_into(out);
                }
                out.push(']');
            }
            Json::Object(fields) => {
                out.push('{');
                for (i, (k, v)) in fields.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    escape_into(k, out);
                    out.push(':');
                    v.render_into(out);
                }
                out.push('}');
            }
        }
    }
}

/// Hostile input can nest `[[[[…` arbitrarily deep; recursion past this
/// many levels is rejected instead of overflowing the stack.
const MAX_PARSE_DEPTH: u32 = 128;

struct Parser<'a> {
    b: &'a [u8],
    i: usize,
}

impl<'a> Parser<'a> {
    fn skip_ws(&mut self) {
        while self.i < self.b.len() && matches!(self.b[self.i], b' ' | b'\t' | b'\n' | b'\r') {
            self.i += 1;
        }
    }

    fn peek(&self) -> Option<u8> {
        self.b.get(self.i).copied()
    }

    fn expect(&mut self, c: u8) -> Result<(), String> {
        if self.peek() == Some(c) {
            self.i += 1;
            Ok(())
        } else {
            Err(format!("expected {:?} at offset {}", c as char, self.i))
        }
    }

    fn eat_literal(&mut self, lit: &str, v: Json) -> Result<Json, String> {
        if self.b[self.i..].starts_with(lit.as_bytes()) {
            self.i += lit.len();
            Ok(v)
        } else {
            Err(format!("bad literal at offset {}", self.i))
        }
    }

    fn value(&mut self, depth: u32) -> Result<Json, String> {
        if depth > MAX_PARSE_DEPTH {
            return Err("nesting too deep".to_string());
        }
        self.skip_ws();
        match self.peek() {
            None => Err("unexpected end of input".to_string()),
            Some(b'n') => self.eat_literal("null", Json::Null),
            Some(b't') => self.eat_literal("true", Json::Bool(true)),
            Some(b'f') => self.eat_literal("false", Json::Bool(false)),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b'[') => {
                self.i += 1;
                let mut items = Vec::new();
                self.skip_ws();
                if self.peek() == Some(b']') {
                    self.i += 1;
                    return Ok(Json::Array(items));
                }
                loop {
                    items.push(self.value(depth + 1)?);
                    self.skip_ws();
                    match self.peek() {
                        Some(b',') => self.i += 1,
                        Some(b']') => {
                            self.i += 1;
                            return Ok(Json::Array(items));
                        }
                        _ => return Err(format!("expected ',' or ']' at offset {}", self.i)),
                    }
                }
            }
            Some(b'{') => {
                self.i += 1;
                let mut fields = Vec::new();
                self.skip_ws();
                if self.peek() == Some(b'}') {
                    self.i += 1;
                    return Ok(Json::Object(fields));
                }
                loop {
                    self.skip_ws();
                    let key = self.string()?;
                    self.skip_ws();
                    self.expect(b':')?;
                    fields.push((key, self.value(depth + 1)?));
                    self.skip_ws();
                    match self.peek() {
                        Some(b',') => self.i += 1,
                        Some(b'}') => {
                            self.i += 1;
                            return Ok(Json::Object(fields));
                        }
                        _ => return Err(format!("expected ',' or '}}' at offset {}", self.i)),
                    }
                }
            }
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            Some(c) => Err(format!("unexpected byte {:?} at offset {}", c as char, self.i)),
        }
    }

    fn string(&mut self) -> Result<String, String> {
        self.expect(b'"')?;
        let mut out = String::new();
        loop {
            match self.peek() {
                None => return Err("unterminated string".to_string()),
                Some(b'"') => {
                    self.i += 1;
                    return Ok(out);
                }
                Some(b'\\') => {
                    self.i += 1;
                    match self.peek() {
                        Some(b'"') => out.push('"'),
                        Some(b'\\') => out.push('\\'),
                        Some(b'/') => out.push('/'),
                        Some(b'n') => out.push('\n'),
                        Some(b'r') => out.push('\r'),
                        Some(b't') => out.push('\t'),
                        Some(b'b') => out.push('\u{0008}'),
                        Some(b'f') => out.push('\u{000c}'),
                        Some(b'u') => {
                            self.i += 1;
                            let hi = self.hex4()?;
                            let c = if (0xD800..0xDC00).contains(&hi) {
                                // Surrogate pair: require a valid low half.
                                if self.b[self.i..].starts_with(b"\\u") {
                                    self.i += 2;
                                    let lo = self.hex4()?;
                                    if (0xDC00..0xE000).contains(&lo) {
                                        let cp = 0x10000 + ((hi - 0xD800) << 10) + (lo - 0xDC00);
                                        char::from_u32(cp)
                                    } else {
                                        None
                                    }
                                } else {
                                    None
                                }
                            } else {
                                char::from_u32(hi)
                            };
                            out.push(c.ok_or_else(|| {
                                format!("bad \\u escape ending at offset {}", self.i)
                            })?);
                            continue; // hex4 already advanced past the digits
                        }
                        _ => return Err(format!("bad escape at offset {}", self.i)),
                    }
                    self.i += 1;
                }
                Some(c) if c < 0x20 => {
                    return Err(format!("raw control byte in string at offset {}", self.i));
                }
                Some(_) => {
                    // Multi-byte UTF-8 sequences pass through verbatim: the
                    // input is a &str, so byte-wise copies stay valid UTF-8.
                    let start = self.i;
                    self.i += 1;
                    while self.i < self.b.len() && (self.b[self.i] & 0xC0) == 0x80 {
                        self.i += 1;
                    }
                    out.push_str(std::str::from_utf8(&self.b[start..self.i]).unwrap());
                }
            }
        }
    }

    fn hex4(&mut self) -> Result<u32, String> {
        if self.i + 4 > self.b.len() {
            return Err("truncated \\u escape".to_string());
        }
        let s = std::str::from_utf8(&self.b[self.i..self.i + 4])
            .map_err(|_| "bad \\u escape".to_string())?;
        let v = u32::from_str_radix(s, 16).map_err(|_| "bad \\u escape".to_string())?;
        self.i += 4;
        Ok(v)
    }

    fn number(&mut self) -> Result<Json, String> {
        let start = self.i;
        if self.peek() == Some(b'-') {
            self.i += 1;
        }
        let mut is_float = false;
        while let Some(c) = self.peek() {
            match c {
                b'0'..=b'9' => self.i += 1,
                b'.' | b'e' | b'E' | b'+' | b'-' => {
                    is_float = true;
                    self.i += 1;
                }
                _ => break,
            }
        }
        let s = std::str::from_utf8(&self.b[start..self.i]).unwrap();
        if !is_float {
            if let Ok(i) = s.parse::<i64>() {
                return Ok(Json::Int(i));
            }
        }
        s.parse::<f64>()
            .map(Json::Float)
            .map_err(|_| format!("bad number {s:?} at offset {start}"))
    }
}

fn escape_into(s: &str, out: &mut String) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => {
                let _ = write!(out, "\\u{:04x}", c as u32);
            }
            c => out.push(c),
        }
    }
    out.push('"');
}

impl From<bool> for Json {
    fn from(v: bool) -> Json {
        Json::Bool(v)
    }
}
impl From<i64> for Json {
    fn from(v: i64) -> Json {
        Json::Int(v)
    }
}
impl From<usize> for Json {
    fn from(v: usize) -> Json {
        Json::Int(v as i64)
    }
}
impl From<u64> for Json {
    fn from(v: u64) -> Json {
        Json::Int(v as i64)
    }
}
impl From<f64> for Json {
    fn from(v: f64) -> Json {
        Json::Float(v)
    }
}
impl From<f32> for Json {
    fn from(v: f32) -> Json {
        Json::Float(v as f64)
    }
}
impl From<&str> for Json {
    fn from(v: &str) -> Json {
        Json::Str(v.to_string())
    }
}
impl From<String> for Json {
    fn from(v: String) -> Json {
        Json::Str(v)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn renders_nested() {
        let mut arr = Json::arr();
        arr.push(1i64);
        arr.push("two");
        let j = Json::obj()
            .set("name", "MatMul")
            .set("dur", 12.5f64)
            .set("ok", true)
            .set("items", arr);
        assert_eq!(
            j.render(),
            r#"{"name":"MatMul","dur":12.5,"ok":true,"items":[1,"two"]}"#
        );
    }

    #[test]
    fn escapes_strings() {
        let j = Json::Str("a\"b\\c\nd".to_string());
        assert_eq!(j.render(), r#""a\"b\\c\nd""#);
    }

    #[test]
    fn non_finite_floats_become_null() {
        assert_eq!(Json::Float(f64::NAN).render(), "null");
        assert_eq!(Json::Float(f64::INFINITY).render(), "null");
    }

    #[test]
    fn parse_roundtrips_writer_output() {
        let mut arr = Json::arr();
        arr.push(1i64);
        arr.push("two");
        arr.push(Json::Null);
        let j = Json::obj()
            .set("name", "Mat\"Mul\n")
            .set("dur", 12.5f64)
            .set("neg", -7i64)
            .set("ok", true)
            .set("items", arr);
        let rendered = j.render();
        let parsed = Json::parse(&rendered).unwrap();
        assert_eq!(parsed.render(), rendered);
        assert_eq!(parsed.get("name").and_then(Json::as_str), Some("Mat\"Mul\n"));
        assert_eq!(parsed.get("dur").and_then(Json::as_f64), Some(12.5));
        assert_eq!(parsed.get("neg").and_then(Json::as_i64), Some(-7));
        assert_eq!(parsed.get("ok").and_then(Json::as_bool), Some(true));
        assert_eq!(parsed.get("items").and_then(Json::as_array).map(<[Json]>::len), Some(3));
    }

    #[test]
    fn parse_accepts_whitespace_and_unicode() {
        let j = Json::parse(" { \"a\" : [ 1 , 2.5e1 ] , \"s\" : \"\\u00e9\\ud83d\\ude00\" } ")
            .unwrap();
        assert_eq!(j.get("a").unwrap().as_array().unwrap()[1].as_f64(), Some(25.0));
        assert_eq!(j.get("s").and_then(Json::as_str), Some("é😀"));
    }

    #[test]
    fn parse_rejects_malformed_without_panic() {
        for bad in [
            "", "{", "[1,", "{\"a\":}", "tru", "\"unterminated", "01a", "[1]x",
            "{\"a\" 1}", "\"\\u12\"", "\"\\ud800x\"", "\u{1}",
        ] {
            assert!(Json::parse(bad).is_err(), "should reject {bad:?}");
        }
        // Depth bomb: deep nesting errors instead of overflowing the stack.
        let bomb = "[".repeat(100_000);
        assert!(Json::parse(&bomb).is_err());
    }
}
