//! A small fixed-size thread pool.
//!
//! Each RustFlow device owns one of these for kernel execution (the paper's
//! per-device "arranging for the execution of kernels", §3 Devices), and the
//! distributed worker uses one for serving RPCs. No work stealing: a shared
//! injector queue with a condvar — profiling (EXPERIMENTS.md §Perf) showed
//! the executor's dispatch overhead dominates long before queue contention
//! does at the device counts we simulate.

use std::collections::VecDeque;
use std::sync::atomic::{AtomicBool, AtomicUsize, Ordering};
use std::sync::{Arc, Condvar, Mutex};
use std::thread::JoinHandle;

type Task = Box<dyn FnOnce() + Send + 'static>;

struct Shared {
    queue: Mutex<VecDeque<Task>>,
    cond: Condvar,
    shutdown: AtomicBool,
    in_flight: AtomicUsize,
    idle_cond: Condvar,
    idle_mutex: Mutex<()>,
}

/// Fixed-size thread pool. Dropping the pool joins all workers.
pub struct ThreadPool {
    shared: Arc<Shared>,
    workers: Vec<JoinHandle<()>>,
    size: usize,
}

impl ThreadPool {
    pub fn new(size: usize, name: &str) -> Self {
        let size = size.max(1);
        let shared = Arc::new(Shared {
            queue: Mutex::new(VecDeque::new()),
            cond: Condvar::new(),
            shutdown: AtomicBool::new(false),
            in_flight: AtomicUsize::new(0),
            idle_cond: Condvar::new(),
            idle_mutex: Mutex::new(()),
        });
        let mut workers = Vec::with_capacity(size);
        for i in 0..size {
            let shared = Arc::clone(&shared);
            let handle = std::thread::Builder::new()
                .name(format!("{name}-{i}"))
                .spawn(move || worker_loop(shared))
                .expect("spawn threadpool worker");
            workers.push(handle);
        }
        ThreadPool { shared, workers, size }
    }

    pub fn size(&self) -> usize {
        self.size
    }

    /// Enqueue a task for execution.
    pub fn execute(&self, task: impl FnOnce() + Send + 'static) {
        self.shared.in_flight.fetch_add(1, Ordering::SeqCst);
        {
            let mut q = self.shared.queue.lock().unwrap();
            q.push_back(Box::new(task));
        }
        self.shared.cond.notify_one();
    }

    /// Block until every enqueued task has finished.
    pub fn wait_idle(&self) {
        let mut guard = self.shared.idle_mutex.lock().unwrap();
        while self.shared.in_flight.load(Ordering::SeqCst) != 0 {
            guard = self.shared.idle_cond.wait(guard).unwrap();
        }
    }
}

fn worker_loop(shared: Arc<Shared>) {
    loop {
        let task = {
            let mut q = shared.queue.lock().unwrap();
            loop {
                if let Some(t) = q.pop_front() {
                    break t;
                }
                if shared.shutdown.load(Ordering::SeqCst) {
                    return;
                }
                q = shared.cond.wait(q).unwrap();
            }
        };
        task();
        if shared.in_flight.fetch_sub(1, Ordering::SeqCst) == 1 {
            let _guard = shared.idle_mutex.lock().unwrap();
            shared.idle_cond.notify_all();
        }
    }
}

impl Drop for ThreadPool {
    fn drop(&mut self) {
        self.shared.shutdown.store(true, Ordering::SeqCst);
        self.shared.cond.notify_all();
        let me = std::thread::current().id();
        for w in self.workers.drain(..) {
            // The pool can be dropped *from* one of its own workers (the
            // last Arc to the owning Device released inside an async-kernel
            // continuation). Joining yourself is EDEADLK; detach instead —
            // the shutdown flag makes the worker exit on its own.
            if w.thread().id() == me {
                continue;
            }
            let _ = w.join();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::AtomicU64;

    #[test]
    fn runs_all_tasks() {
        let pool = ThreadPool::new(4, "test");
        let counter = Arc::new(AtomicU64::new(0));
        for _ in 0..1000 {
            let c = Arc::clone(&counter);
            pool.execute(move || {
                c.fetch_add(1, Ordering::SeqCst);
            });
        }
        pool.wait_idle();
        assert_eq!(counter.load(Ordering::SeqCst), 1000);
    }

    #[test]
    fn wait_idle_with_no_tasks_returns() {
        let pool = ThreadPool::new(2, "test");
        pool.wait_idle();
    }

    #[test]
    fn tasks_can_enqueue_tasks() {
        let pool = Arc::new(ThreadPool::new(2, "test"));
        let counter = Arc::new(AtomicU64::new(0));
        {
            let pool2 = Arc::clone(&pool);
            let c = Arc::clone(&counter);
            pool.execute(move || {
                for _ in 0..10 {
                    let c = Arc::clone(&c);
                    pool2.execute(move || {
                        c.fetch_add(1, Ordering::SeqCst);
                    });
                }
            });
        }
        // Spin until nested tasks finish (wait_idle covers them because
        // in_flight is bumped before enqueue).
        while counter.load(Ordering::SeqCst) < 10 {
            std::thread::yield_now();
        }
        assert_eq!(counter.load(Ordering::SeqCst), 10);
    }

    #[test]
    fn drop_joins_workers() {
        let pool = ThreadPool::new(3, "drop");
        let counter = Arc::new(AtomicU64::new(0));
        for _ in 0..50 {
            let c = Arc::clone(&counter);
            pool.execute(move || {
                c.fetch_add(1, Ordering::SeqCst);
            });
        }
        pool.wait_idle();
        drop(pool);
        assert_eq!(counter.load(Ordering::SeqCst), 50);
    }
}
