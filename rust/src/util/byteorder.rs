//! Little-endian byte packing, shared by every on-disk and on-wire format
//! (`tensor::codec`, `graph::serde`, `checkpoint`, `data`,
//! `distributed::proto`).
//!
//! Drop-in subset of the `byteorder` crate's `LittleEndian` API so the
//! crate builds with no external dependencies: reads take a slice at least
//! as long as the value, writes fill the first `size_of::<T>()` bytes.
//! Both panic on short buffers, exactly like the original.

/// Little-endian reader/writer. All methods are associated functions, used
/// as `LittleEndian::read_u32(&buf[pos..])`.
pub struct LittleEndian;

macro_rules! impl_le {
    ($($read:ident / $write:ident => $ty:ty),* $(,)?) => {
        impl LittleEndian {
            $(
                pub fn $read(buf: &[u8]) -> $ty {
                    const N: usize = std::mem::size_of::<$ty>();
                    let mut bytes = [0u8; N];
                    bytes.copy_from_slice(&buf[..N]);
                    <$ty>::from_le_bytes(bytes)
                }

                pub fn $write(buf: &mut [u8], v: $ty) {
                    const N: usize = std::mem::size_of::<$ty>();
                    buf[..N].copy_from_slice(&v.to_le_bytes());
                }
            )*
        }
    };
}

impl_le! {
    read_u16 / write_u16 => u16,
    read_u32 / write_u32 => u32,
    read_u64 / write_u64 => u64,
    read_i32 / write_i32 => i32,
    read_i64 / write_i64 => i64,
    read_f32 / write_f32 => f32,
    read_f64 / write_f64 => f64,
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_all_widths() {
        let mut b = [0u8; 8];
        LittleEndian::write_u16(&mut b, 0xBEEF);
        assert_eq!(LittleEndian::read_u16(&b), 0xBEEF);
        LittleEndian::write_u32(&mut b, 0xDEAD_BEEF);
        assert_eq!(LittleEndian::read_u32(&b), 0xDEAD_BEEF);
        LittleEndian::write_u64(&mut b, u64::MAX - 7);
        assert_eq!(LittleEndian::read_u64(&b), u64::MAX - 7);
        LittleEndian::write_i32(&mut b, -42);
        assert_eq!(LittleEndian::read_i32(&b), -42);
        LittleEndian::write_i64(&mut b, i64::MIN + 1);
        assert_eq!(LittleEndian::read_i64(&b), i64::MIN + 1);
        LittleEndian::write_f32(&mut b, 3.25);
        assert_eq!(LittleEndian::read_f32(&b), 3.25);
        LittleEndian::write_f64(&mut b, -0.5);
        assert_eq!(LittleEndian::read_f64(&b), -0.5);
    }

    #[test]
    fn little_endian_layout() {
        let mut b = [0u8; 4];
        LittleEndian::write_u32(&mut b, 0x0102_0304);
        assert_eq!(b, [0x04, 0x03, 0x02, 0x01]);
    }

    #[test]
    fn reads_ignore_trailing_bytes() {
        let b = [0x01, 0x00, 0xFF, 0xFF, 0xFF];
        assert_eq!(LittleEndian::read_u16(&b), 1);
    }
}
