//! Filesystem helpers shared by the on-disk artifact writers
//! (`checkpoint` bundles, `graph::serde` GraphDef files).

use crate::error::Result;
use std::path::Path;
use std::sync::atomic::{AtomicU64, Ordering};

/// Process-wide sequence for unique temp names: two concurrent writers
/// in one directory must never share a temp path (a shared
/// `foo.tmp`-style name corrupts one artifact when e.g. `v1.graphdef`
/// and `v1.ckpt` export in parallel).
static TMP_SEQ: AtomicU64 = AtomicU64::new(0);

/// Write `bytes` to `path` atomically: a uniquely named temp file in the
/// same directory (rename must not cross filesystems), fsync, then
/// rename over the target — a crash mid-write never corrupts an
/// existing artifact. Creates missing parent directories.
pub fn atomic_write(path: &Path, bytes: &[u8]) -> Result<()> {
    use std::io::Write as _;
    if let Some(parent) = path.parent() {
        std::fs::create_dir_all(parent)?;
    }
    let file_name = path
        .file_name()
        .map(|n| n.to_string_lossy().into_owned())
        .unwrap_or_else(|| "artifact".to_string());
    let tmp = path.with_file_name(format!(
        ".{file_name}.tmp.{}.{}",
        std::process::id(),
        TMP_SEQ.fetch_add(1, Ordering::Relaxed)
    ));
    let mut f = std::fs::File::create(&tmp)?;
    f.write_all(bytes)?;
    f.sync_all()?;
    if let Err(e) = std::fs::rename(&tmp, path) {
        let _ = std::fs::remove_file(&tmp);
        return Err(e.into());
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tmpdir() -> std::path::PathBuf {
        let d = std::env::temp_dir().join(format!("rustflow-fsutil-{}", std::process::id()));
        let _ = std::fs::create_dir_all(&d);
        d
    }

    #[test]
    fn writes_and_replaces() {
        let path = tmpdir().join("artifact.bin");
        atomic_write(&path, b"one").unwrap();
        assert_eq!(std::fs::read(&path).unwrap(), b"one");
        atomic_write(&path, b"two").unwrap();
        assert_eq!(std::fs::read(&path).unwrap(), b"two");
    }

    #[test]
    fn no_temp_residue_and_no_cross_artifact_collision() {
        let dir = tmpdir();
        // Same stem, different extensions — the modelhub layout.
        let a = dir.join("v1.graphdef");
        let b = dir.join("v1.ckpt");
        atomic_write(&a, b"graph").unwrap();
        atomic_write(&b, b"ckpt").unwrap();
        assert_eq!(std::fs::read(&a).unwrap(), b"graph");
        assert_eq!(std::fs::read(&b).unwrap(), b"ckpt");
        let residue: Vec<_> = std::fs::read_dir(&dir)
            .unwrap()
            .filter_map(|e| e.ok())
            .filter(|e| e.file_name().to_string_lossy().contains(".tmp."))
            .collect();
        assert!(residue.is_empty(), "temp files left behind: {residue:?}");
    }

    #[test]
    fn creates_parent_dirs() {
        let path = tmpdir().join("deep/nested/artifact.bin");
        atomic_write(&path, b"x").unwrap();
        assert_eq!(std::fs::read(&path).unwrap(), b"x");
    }
}
