//! Shared utilities: PRNG, JSON writer, thread pool, bench stats.

pub mod json;
pub mod rng;
pub mod stats;
pub mod threadpool;
