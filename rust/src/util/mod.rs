//! Shared utilities: PRNG, JSON writer, thread pool, bench stats,
//! little-endian byte packing, and a bounded MPMC channel.

pub mod bounded;
pub mod byteorder;
pub mod json;
pub mod rng;
pub mod stats;
pub mod threadpool;
