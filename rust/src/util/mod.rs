//! Shared utilities: PRNG, JSON writer, thread pool, bench stats,
//! little-endian byte packing, a bounded MPMC channel, and atomic
//! artifact writes.

pub mod bounded;
pub mod byteorder;
pub mod fsutil;
pub mod json;
pub mod rng;
pub mod stats;
pub mod threadpool;
