//! Timing statistics used by the bench harness (`rust/benches/*`) and the
//! experiment drivers. The image carries no `criterion`, so `cargo bench`
//! targets use `harness = false` with this module: warmup, timed
//! iterations, then mean / p50 / p95 / p99 over per-iteration samples.

use std::time::{Duration, Instant};

/// Summary statistics over a set of duration samples.
#[derive(Debug, Clone)]
pub struct Summary {
    pub iters: usize,
    pub mean: Duration,
    pub p50: Duration,
    pub p95: Duration,
    pub p99: Duration,
    pub min: Duration,
    pub max: Duration,
    pub total: Duration,
}

impl Summary {
    pub fn from_samples(mut samples: Vec<Duration>) -> Summary {
        assert!(!samples.is_empty());
        samples.sort();
        let total: Duration = samples.iter().sum();
        let pct = |p: f64| -> Duration {
            let idx = ((samples.len() as f64 - 1.0) * p).round() as usize;
            samples[idx]
        };
        Summary {
            iters: samples.len(),
            mean: total / samples.len() as u32,
            p50: pct(0.50),
            p95: pct(0.95),
            p99: pct(0.99),
            min: samples[0],
            max: *samples.last().unwrap(),
            total,
        }
    }

    /// Throughput in items/sec given `items` processed per iteration.
    pub fn throughput(&self, items_per_iter: f64) -> f64 {
        items_per_iter / self.mean.as_secs_f64()
    }
}

/// Run `f` for `warmup` untimed and `iters` timed iterations.
pub fn bench<F: FnMut()>(warmup: usize, iters: usize, mut f: F) -> Summary {
    for _ in 0..warmup {
        f();
    }
    let mut samples = Vec::with_capacity(iters);
    for _ in 0..iters {
        let t = Instant::now();
        f();
        samples.push(t.elapsed());
    }
    Summary::from_samples(samples)
}

/// Bench with a time budget instead of a fixed iteration count.
pub fn bench_for<F: FnMut()>(warmup: usize, budget: Duration, mut f: F) -> Summary {
    for _ in 0..warmup {
        f();
    }
    let mut samples = Vec::new();
    let start = Instant::now();
    while start.elapsed() < budget || samples.is_empty() {
        let t = Instant::now();
        f();
        samples.push(t.elapsed());
        if samples.len() >= 1_000_000 {
            break;
        }
    }
    Summary::from_samples(samples)
}

/// Render one bench row, criterion-ish.
pub fn report(name: &str, s: &Summary) {
    println!(
        "{name:<48} mean {:>12?}  p50 {:>12?}  p95 {:>12?}  p99 {:>12?}  ({} iters)",
        s.mean, s.p50, s.p95, s.p99, s.iters
    );
}

/// Render one bench row with a throughput column.
pub fn report_throughput(name: &str, s: &Summary, items_per_iter: f64, unit: &str) {
    println!(
        "{name:<48} mean {:>12?}  p50 {:>12?}  {:>14.1} {unit}/s  ({} iters)",
        s.mean,
        s.p50,
        s.throughput(items_per_iter),
        s.iters
    );
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn summary_percentiles_ordered() {
        let samples: Vec<Duration> = (1..=100).map(Duration::from_micros).collect();
        let s = Summary::from_samples(samples);
        assert!(s.min <= s.p50 && s.p50 <= s.p95 && s.p95 <= s.p99 && s.p99 <= s.max);
        assert_eq!(s.iters, 100);
        assert_eq!(s.min, Duration::from_micros(1));
        assert_eq!(s.max, Duration::from_micros(100));
    }

    #[test]
    fn bench_runs_expected_iterations() {
        let mut count = 0;
        let s = bench(2, 10, || {
            count += 1;
        });
        assert_eq!(count, 12);
        assert_eq!(s.iters, 10);
    }

    #[test]
    fn throughput_positive() {
        let s = bench(0, 5, || {
            std::hint::black_box(1 + 1);
        });
        assert!(s.throughput(100.0) > 0.0);
    }
}
