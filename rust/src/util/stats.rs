//! Timing statistics used by the bench harness (`rust/benches/*`) and the
//! experiment drivers. The image carries no `criterion`, so `cargo bench`
//! targets use `harness = false` with this module: warmup, timed
//! iterations, then mean / p50 / p95 / p99 over per-iteration samples.

use std::sync::atomic::{AtomicU64, Ordering};
use std::time::{Duration, Instant};

/// Summary statistics over a set of duration samples.
#[derive(Debug, Clone)]
pub struct Summary {
    pub iters: usize,
    pub mean: Duration,
    pub p50: Duration,
    pub p95: Duration,
    pub p99: Duration,
    pub min: Duration,
    pub max: Duration,
    pub total: Duration,
}

impl Summary {
    pub fn from_samples(mut samples: Vec<Duration>) -> Summary {
        assert!(!samples.is_empty());
        samples.sort();
        let total: Duration = samples.iter().sum();
        let pct = |p: f64| -> Duration {
            let idx = ((samples.len() as f64 - 1.0) * p).round() as usize;
            samples[idx]
        };
        Summary {
            iters: samples.len(),
            mean: total / samples.len() as u32,
            p50: pct(0.50),
            p95: pct(0.95),
            p99: pct(0.99),
            min: samples[0],
            max: *samples.last().unwrap(),
            total,
        }
    }

    /// Throughput in items/sec given `items` processed per iteration.
    pub fn throughput(&self, items_per_iter: f64) -> f64 {
        items_per_iter / self.mean.as_secs_f64()
    }
}

/// Number of log2 latency buckets: bucket 0 is `< 1µs`, bucket `i ≥ 1`
/// covers `[2^(i-1), 2^i)` µs, and the last bucket is a catch-all
/// (`≥ 2^38` µs ≈ 76 hours).
const LATENCY_BUCKETS: usize = 40;

/// A lock-free streaming latency histogram with power-of-two microsecond
/// buckets, built for concurrent recorders (the serving layer's
/// per-model-version stats). Unlike [`Summary`], which post-processes a
/// vector of samples, this never stores samples: `record` is a couple of
/// relaxed atomic adds, and quantiles are estimated from the bucket
/// counts (linear interpolation within a bucket, so estimates carry up
/// to one bucket — ~2× — of resolution error). Counters are monotonic;
/// a snapshot taken while recorders are active is approximate but never
/// tears.
#[derive(Debug)]
pub struct LatencyHistogram {
    buckets: [AtomicU64; LATENCY_BUCKETS],
    count: AtomicU64,
    sum_micros: AtomicU64,
    max_micros: AtomicU64,
}

impl Default for LatencyHistogram {
    fn default() -> Self {
        LatencyHistogram {
            buckets: std::array::from_fn(|_| AtomicU64::new(0)),
            count: AtomicU64::new(0),
            sum_micros: AtomicU64::new(0),
            max_micros: AtomicU64::new(0),
        }
    }
}

impl LatencyHistogram {
    /// Number of log2 buckets, for exposition-format exporters.
    pub const NUM_BUCKETS: usize = LATENCY_BUCKETS;

    pub fn new() -> LatencyHistogram {
        LatencyHistogram::default()
    }

    /// Bucket index for a duration of `us` microseconds.
    fn bucket_of(us: u64) -> usize {
        if us == 0 {
            0
        } else {
            ((64 - us.leading_zeros()) as usize).min(LATENCY_BUCKETS - 1)
        }
    }

    pub fn record(&self, d: Duration) {
        let us = d.as_micros().min(u64::MAX as u128) as u64;
        self.buckets[Self::bucket_of(us)].fetch_add(1, Ordering::Relaxed);
        self.count.fetch_add(1, Ordering::Relaxed);
        self.sum_micros.fetch_add(us, Ordering::Relaxed);
        self.max_micros.fetch_max(us, Ordering::Relaxed);
    }

    pub fn count(&self) -> u64 {
        self.count.load(Ordering::Relaxed)
    }

    /// Total of every recorded value, in microseconds. Together with
    /// [`LatencyHistogram::bucket_counts`] this is everything a
    /// Prometheus-style exposition of the histogram needs.
    pub fn sum_micros(&self) -> u64 {
        self.sum_micros.load(Ordering::Relaxed)
    }

    /// Snapshot of the raw per-bucket counts
    /// (length [`LatencyHistogram::NUM_BUCKETS`]). Approximate under
    /// concurrent recorders, never torn per bucket.
    pub fn bucket_counts(&self) -> Vec<u64> {
        self.buckets.iter().map(|b| b.load(Ordering::Relaxed)).collect()
    }

    /// Inclusive upper bound of bucket `i` in microseconds: bucket 0
    /// holds only 0µs values, bucket `i ≥ 1` covers `[2^(i-1), 2^i)` µs
    /// so its inclusive bound is `2^i - 1`. Returns `None` for the final
    /// catch-all bucket (and any out-of-range index) — i.e. `+Inf`.
    pub fn bucket_upper_micros(i: usize) -> Option<u64> {
        match i {
            0 => Some(0),
            _ if i < LATENCY_BUCKETS - 1 => Some((1u64 << i) - 1),
            _ => None,
        }
    }

    /// Estimated quantile `q ∈ [0, 1]`; `Duration::ZERO` when empty.
    pub fn quantile(&self, q: f64) -> Duration {
        let counts: Vec<u64> =
            self.buckets.iter().map(|b| b.load(Ordering::Relaxed)).collect();
        let total: u64 = counts.iter().sum();
        if total == 0 {
            return Duration::ZERO;
        }
        let rank = ((q.clamp(0.0, 1.0) * total as f64).ceil() as u64).max(1);
        let mut cum = 0u64;
        for (i, &c) in counts.iter().enumerate() {
            if c > 0 && cum + c >= rank {
                let lower = if i == 0 { 0u64 } else { 1u64 << (i - 1) };
                let upper = 1u64 << i;
                let frac = (rank - cum) as f64 / c as f64;
                let us = lower as f64 + frac * (upper - lower) as f64;
                return Duration::from_micros(us as u64);
            }
            cum += c;
        }
        Duration::from_micros(self.max_micros.load(Ordering::Relaxed))
    }

    pub fn summary(&self) -> LatencySummary {
        let count = self.count();
        let mean = if count == 0 {
            Duration::ZERO
        } else {
            Duration::from_micros(self.sum_micros.load(Ordering::Relaxed) / count)
        };
        LatencySummary {
            count,
            mean,
            p50: self.quantile(0.50),
            p95: self.quantile(0.95),
            p99: self.quantile(0.99),
            max: Duration::from_micros(self.max_micros.load(Ordering::Relaxed)),
        }
    }
}

/// Point-in-time view of a [`LatencyHistogram`].
#[derive(Debug, Clone, Default)]
pub struct LatencySummary {
    pub count: u64,
    pub mean: Duration,
    pub p50: Duration,
    pub p95: Duration,
    pub p99: Duration,
    pub max: Duration,
}

/// Run `f` for `warmup` untimed and `iters` timed iterations.
pub fn bench<F: FnMut()>(warmup: usize, iters: usize, mut f: F) -> Summary {
    for _ in 0..warmup {
        f();
    }
    let mut samples = Vec::with_capacity(iters);
    for _ in 0..iters {
        let t = Instant::now();
        f();
        samples.push(t.elapsed());
    }
    Summary::from_samples(samples)
}

/// Bench with a time budget instead of a fixed iteration count.
pub fn bench_for<F: FnMut()>(warmup: usize, budget: Duration, mut f: F) -> Summary {
    for _ in 0..warmup {
        f();
    }
    let mut samples = Vec::new();
    let start = Instant::now();
    while start.elapsed() < budget || samples.is_empty() {
        let t = Instant::now();
        f();
        samples.push(t.elapsed());
        if samples.len() >= 1_000_000 {
            break;
        }
    }
    Summary::from_samples(samples)
}

/// Render one bench row, criterion-ish.
pub fn report(name: &str, s: &Summary) {
    println!(
        "{name:<48} mean {:>12?}  p50 {:>12?}  p95 {:>12?}  p99 {:>12?}  ({} iters)",
        s.mean, s.p50, s.p95, s.p99, s.iters
    );
}

/// Render one bench row with a throughput column.
pub fn report_throughput(name: &str, s: &Summary, items_per_iter: f64, unit: &str) {
    println!(
        "{name:<48} mean {:>12?}  p50 {:>12?}  {:>14.1} {unit}/s  ({} iters)",
        s.mean,
        s.p50,
        s.throughput(items_per_iter),
        s.iters
    );
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn summary_percentiles_ordered() {
        let samples: Vec<Duration> = (1..=100).map(Duration::from_micros).collect();
        let s = Summary::from_samples(samples);
        assert!(s.min <= s.p50 && s.p50 <= s.p95 && s.p95 <= s.p99 && s.p99 <= s.max);
        assert_eq!(s.iters, 100);
        assert_eq!(s.min, Duration::from_micros(1));
        assert_eq!(s.max, Duration::from_micros(100));
    }

    #[test]
    fn bench_runs_expected_iterations() {
        let mut count = 0;
        let s = bench(2, 10, || {
            count += 1;
        });
        assert_eq!(count, 12);
        assert_eq!(s.iters, 10);
    }

    #[test]
    fn histogram_quantiles_ordered_and_bounded() {
        let h = LatencyHistogram::new();
        assert_eq!(h.quantile(0.5), Duration::ZERO);
        for us in 1..=1000u64 {
            h.record(Duration::from_micros(us));
        }
        let s = h.summary();
        assert_eq!(s.count, 1000);
        assert!(s.p50 <= s.p95 && s.p95 <= s.p99, "{s:?}");
        assert_eq!(s.max, Duration::from_micros(1000));
        // Log-bucket resolution: estimates are within one power of two.
        assert!(s.p50 >= Duration::from_micros(256) && s.p50 <= Duration::from_micros(1024));
        assert!(s.p99 >= Duration::from_micros(512) && s.p99 <= Duration::from_micros(1024));
        assert!(s.mean >= Duration::from_micros(400) && s.mean <= Duration::from_micros(600));
    }

    #[test]
    fn histogram_concurrent_recorders() {
        let h = std::sync::Arc::new(LatencyHistogram::new());
        let mut handles = Vec::new();
        for _ in 0..4 {
            let h = std::sync::Arc::clone(&h);
            handles.push(std::thread::spawn(move || {
                for us in 0..500u64 {
                    h.record(Duration::from_micros(us));
                }
            }));
        }
        for t in handles {
            t.join().unwrap();
        }
        assert_eq!(h.count(), 2000);
        assert!(h.quantile(1.0) <= Duration::from_micros(512));
    }

    #[test]
    fn bucket_of_edges() {
        assert_eq!(LatencyHistogram::bucket_of(0), 0);
        assert_eq!(LatencyHistogram::bucket_of(1), 1);
        assert_eq!(LatencyHistogram::bucket_of(2), 2);
        assert_eq!(LatencyHistogram::bucket_of(3), 2);
        assert_eq!(LatencyHistogram::bucket_of(4), 3);
        assert_eq!(LatencyHistogram::bucket_of(u64::MAX), LATENCY_BUCKETS - 1);
    }

    #[test]
    fn bucket_accessors_expose_raw_shape() {
        let h = LatencyHistogram::new();
        h.record(Duration::ZERO);
        h.record(Duration::from_micros(1));
        h.record(Duration::from_micros(3));
        let counts = h.bucket_counts();
        assert_eq!(counts.len(), LatencyHistogram::NUM_BUCKETS);
        assert_eq!(counts[0], 1);
        assert_eq!(counts[1], 1);
        assert_eq!(counts[2], 1);
        assert_eq!(counts.iter().sum::<u64>(), h.count());
        assert_eq!(h.sum_micros(), 4);
        assert_eq!(LatencyHistogram::bucket_upper_micros(0), Some(0));
        assert_eq!(LatencyHistogram::bucket_upper_micros(1), Some(1));
        assert_eq!(LatencyHistogram::bucket_upper_micros(2), Some(3));
        assert_eq!(LatencyHistogram::bucket_upper_micros(3), Some(7));
        assert_eq!(
            LatencyHistogram::bucket_upper_micros(LatencyHistogram::NUM_BUCKETS - 1),
            None
        );
        assert_eq!(LatencyHistogram::bucket_upper_micros(LatencyHistogram::NUM_BUCKETS), None);
    }

    #[test]
    fn throughput_positive() {
        let s = bench(0, 5, || {
            std::hint::black_box(1 + 1);
        });
        assert!(s.throughput(100.0) > 0.0);
    }
}
