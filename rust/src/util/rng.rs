//! Small, fast, dependency-free PRNGs.
//!
//! The image has no `rand` crate, so RustFlow carries its own:
//! `SplitMix64` for seeding and `Pcg32` as the workhorse generator used by
//! random-init ops, the shuffling queue, and the property-test harness.

/// SplitMix64 — used to expand a single u64 seed into stream state.
#[derive(Debug, Clone)]
pub struct SplitMix64 {
    state: u64,
}

impl SplitMix64 {
    pub fn new(seed: u64) -> Self {
        SplitMix64 { state: seed }
    }

    pub fn next_u64(&mut self) -> u64 {
        self.state = self.state.wrapping_add(0x9E3779B97F4A7C15);
        let mut z = self.state;
        z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
        z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
        z ^ (z >> 31)
    }
}

/// PCG-XSH-RR 64/32: solid statistical quality, 16 bytes of state.
#[derive(Debug, Clone)]
pub struct Pcg32 {
    state: u64,
    inc: u64,
}

impl Pcg32 {
    pub fn new(seed: u64) -> Self {
        Self::with_stream(seed, 0xda3e39cb94b95bdb)
    }

    pub fn with_stream(seed: u64, stream: u64) -> Self {
        let mut sm = SplitMix64::new(seed);
        let mut rng = Pcg32 { state: 0, inc: (stream << 1) | 1 };
        rng.state = sm.next_u64();
        rng.next_u32();
        rng
    }

    pub fn next_u32(&mut self) -> u32 {
        let old = self.state;
        self.state = old.wrapping_mul(6364136223846793005).wrapping_add(self.inc);
        let xorshifted = (((old >> 18) ^ old) >> 27) as u32;
        let rot = (old >> 59) as u32;
        xorshifted.rotate_right(rot)
    }

    pub fn next_u64(&mut self) -> u64 {
        ((self.next_u32() as u64) << 32) | self.next_u32() as u64
    }

    /// Uniform in [0, 1).
    pub fn next_f32(&mut self) -> f32 {
        (self.next_u32() >> 8) as f32 * (1.0 / (1u32 << 24) as f32)
    }

    /// Uniform in [0, 1).
    pub fn next_f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform in [lo, hi).
    pub fn uniform(&mut self, lo: f32, hi: f32) -> f32 {
        lo + (hi - lo) * self.next_f32()
    }

    /// Uniform integer in [0, n). Rejection-free Lemire reduction.
    pub fn next_below(&mut self, n: u32) -> u32 {
        ((self.next_u32() as u64 * n as u64) >> 32) as u32
    }

    /// Uniform usize in [0, n).
    pub fn index(&mut self, n: usize) -> usize {
        debug_assert!(n > 0);
        (self.next_u64() % n as u64) as usize
    }

    /// Standard normal via Box–Muller.
    pub fn normal(&mut self) -> f32 {
        loop {
            let u1 = self.next_f32();
            if u1 <= f32::EPSILON {
                continue;
            }
            let u2 = self.next_f32();
            let r = (-2.0 * u1.ln()).sqrt();
            return r * (2.0 * std::f32::consts::PI * u2).cos();
        }
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.index(i + 1);
            xs.swap(i, j);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_for_seed() {
        let mut a = Pcg32::new(42);
        let mut b = Pcg32::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u32(), b.next_u32());
        }
    }

    #[test]
    fn different_seeds_differ() {
        let mut a = Pcg32::new(1);
        let mut b = Pcg32::new(2);
        let same = (0..32).filter(|_| a.next_u32() == b.next_u32()).count();
        assert!(same < 4);
    }

    #[test]
    fn f32_in_unit_interval() {
        let mut r = Pcg32::new(7);
        for _ in 0..10_000 {
            let x = r.next_f32();
            assert!((0.0..1.0).contains(&x));
        }
    }

    #[test]
    fn uniform_mean_reasonable() {
        let mut r = Pcg32::new(3);
        let n = 50_000;
        let mean: f64 = (0..n).map(|_| r.next_f32() as f64).sum::<f64>() / n as f64;
        assert!((mean - 0.5).abs() < 0.01, "mean={mean}");
    }

    #[test]
    fn normal_moments() {
        let mut r = Pcg32::new(11);
        let n = 50_000;
        let xs: Vec<f64> = (0..n).map(|_| r.normal() as f64).collect();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / n as f64;
        assert!(mean.abs() < 0.03, "mean={mean}");
        assert!((var - 1.0).abs() < 0.05, "var={var}");
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Pcg32::new(5);
        let mut xs: Vec<u32> = (0..100).collect();
        r.shuffle(&mut xs);
        let mut sorted = xs.clone();
        sorted.sort();
        assert_eq!(sorted, (0..100).collect::<Vec<_>>());
        assert_ne!(xs, (0..100).collect::<Vec<_>>());
    }

    #[test]
    fn next_below_bounds() {
        let mut r = Pcg32::new(9);
        for _ in 0..10_000 {
            assert!(r.next_below(17) < 17);
        }
    }
}
