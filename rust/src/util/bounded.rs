//! A bounded multi-producer/multi-consumer queue with blocking push
//! (backpressure), non-blocking try-push (load shedding), and
//! deadline-aware pop — the admission-control primitive under
//! [`crate::serving`]'s batch scheduler.
//!
//! Unlike [`crate::queue`] (graph-level tensor queues whose Enqueue/Dequeue
//! kernels park *continuations*), this is a plain host-side channel for
//! arbitrary `T`: callers are real threads that can afford to block.

use crate::error::{Result, Status};
use std::collections::VecDeque;
use std::sync::{Condvar, Mutex};
use std::time::Instant;

/// Outcome of a deadline-bounded pop.
pub enum Pop<T> {
    /// An item arrived before the deadline.
    Item(T),
    /// The deadline passed with the queue still empty.
    TimedOut,
    /// The queue is closed and fully drained; no item will ever arrive.
    Closed,
}

struct State<T> {
    buf: VecDeque<T>,
    closed: bool,
}

/// Bounded blocking MPMC queue. `push` blocks while full (backpressure),
/// `try_push` fails fast with `ResourceExhausted`, `pop` blocks while
/// empty, and `pop_deadline` gives up at an `Instant`. After `close`,
/// pushes fail with `Unavailable` and pops drain the remaining items
/// before reporting closure.
pub struct Bounded<T> {
    capacity: usize,
    state: Mutex<State<T>>,
    not_empty: Condvar,
    not_full: Condvar,
}

impl<T> Bounded<T> {
    pub fn new(capacity: usize) -> Bounded<T> {
        Bounded {
            capacity: capacity.max(1),
            state: Mutex::new(State { buf: VecDeque::new(), closed: false }),
            not_empty: Condvar::new(),
            not_full: Condvar::new(),
        }
    }

    pub fn capacity(&self) -> usize {
        self.capacity
    }

    pub fn len(&self) -> usize {
        self.state.lock().unwrap().buf.len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    pub fn is_closed(&self) -> bool {
        self.state.lock().unwrap().closed
    }

    /// Blocking push: waits while the queue is at capacity.
    pub fn push(&self, item: T) -> Result<()> {
        let mut s = self.state.lock().unwrap();
        while s.buf.len() >= self.capacity && !s.closed {
            s = self.not_full.wait(s).unwrap();
        }
        if s.closed {
            return Err(Status::unavailable("queue is closed"));
        }
        s.buf.push_back(item);
        drop(s);
        self.not_empty.notify_one();
        Ok(())
    }

    /// Non-blocking push: `ResourceExhausted` when full, `Unavailable`
    /// when closed.
    pub fn try_push(&self, item: T) -> Result<()> {
        let mut s = self.state.lock().unwrap();
        if s.closed {
            return Err(Status::unavailable("queue is closed"));
        }
        if s.buf.len() >= self.capacity {
            return Err(Status::resource_exhausted(format!(
                "queue is full ({} items)",
                self.capacity
            )));
        }
        s.buf.push_back(item);
        drop(s);
        self.not_empty.notify_one();
        Ok(())
    }

    /// Blocking pop: `None` once the queue is closed and drained.
    pub fn pop(&self) -> Option<T> {
        let mut s = self.state.lock().unwrap();
        loop {
            if let Some(item) = s.buf.pop_front() {
                drop(s);
                self.not_full.notify_one();
                return Some(item);
            }
            if s.closed {
                return None;
            }
            s = self.not_empty.wait(s).unwrap();
        }
    }

    /// Non-blocking pop: `TimedOut` means "empty right now".
    pub fn try_pop(&self) -> Pop<T> {
        self.pop_deadline(Instant::now())
    }

    /// Pop with a deadline: blocks until an item arrives, the queue
    /// closes, or `deadline` passes.
    pub fn pop_deadline(&self, deadline: Instant) -> Pop<T> {
        let mut s = self.state.lock().unwrap();
        loop {
            if let Some(item) = s.buf.pop_front() {
                drop(s);
                self.not_full.notify_one();
                return Pop::Item(item);
            }
            if s.closed {
                return Pop::Closed;
            }
            let now = Instant::now();
            if now >= deadline {
                return Pop::TimedOut;
            }
            let (guard, _timeout) = self.not_empty.wait_timeout(s, deadline - now).unwrap();
            s = guard;
        }
    }

    /// Close the queue: wakes all waiters. Already-buffered items remain
    /// poppable; new pushes fail.
    pub fn close(&self) {
        self.state.lock().unwrap().closed = true;
        self.not_empty.notify_all();
        self.not_full.notify_all();
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::Arc;
    use std::time::Duration;

    #[test]
    fn fifo_roundtrip() {
        let q = Bounded::new(4);
        q.push(1).unwrap();
        q.push(2).unwrap();
        assert_eq!(q.len(), 2);
        assert_eq!(q.pop(), Some(1));
        assert_eq!(q.pop(), Some(2));
    }

    #[test]
    fn try_push_backpressure() {
        let q = Bounded::new(2);
        q.try_push(1).unwrap();
        q.try_push(2).unwrap();
        let e = q.try_push(3).unwrap_err();
        assert_eq!(e.code, crate::error::Code::ResourceExhausted);
        q.pop();
        q.try_push(3).unwrap();
    }

    #[test]
    fn push_blocks_until_space() {
        let q = Arc::new(Bounded::new(1));
        q.push(1).unwrap();
        let q2 = Arc::clone(&q);
        let t = std::thread::spawn(move || q2.push(2).unwrap());
        std::thread::sleep(Duration::from_millis(20));
        assert_eq!(q.len(), 1, "second push should still be parked");
        assert_eq!(q.pop(), Some(1));
        t.join().unwrap();
        assert_eq!(q.pop(), Some(2));
    }

    #[test]
    fn try_pop_never_blocks() {
        let q = Bounded::new(4);
        match q.try_pop() {
            Pop::TimedOut => {}
            _ => panic!("expected empty"),
        }
        q.push(5).unwrap();
        match q.try_pop() {
            Pop::Item(5) => {}
            _ => panic!("expected item"),
        }
        q.close();
        match q.try_pop() {
            Pop::Closed => {}
            _ => panic!("expected closed"),
        }
    }

    #[test]
    fn pop_deadline_times_out() {
        let q: Bounded<i32> = Bounded::new(4);
        let t = Instant::now();
        match q.pop_deadline(t + Duration::from_millis(25)) {
            Pop::TimedOut => {}
            _ => panic!("expected timeout"),
        }
        assert!(t.elapsed() >= Duration::from_millis(25));
    }

    #[test]
    fn close_drains_then_reports_closed() {
        let q = Bounded::new(4);
        q.push(7).unwrap();
        q.close();
        assert!(q.push(8).is_err());
        assert_eq!(q.pop(), Some(7));
        assert_eq!(q.pop(), None);
        match q.pop_deadline(Instant::now() + Duration::from_millis(5)) {
            Pop::Closed => {}
            _ => panic!("expected closed"),
        }
    }

    #[test]
    fn close_wakes_blocked_popper() {
        let q: Arc<Bounded<i32>> = Arc::new(Bounded::new(4));
        let q2 = Arc::clone(&q);
        let t = std::thread::spawn(move || q2.pop());
        std::thread::sleep(Duration::from_millis(10));
        q.close();
        assert_eq!(t.join().unwrap(), None);
    }

    #[test]
    fn many_producers_one_consumer() {
        let q = Arc::new(Bounded::new(8));
        let mut handles = Vec::new();
        for p in 0..4 {
            let q = Arc::clone(&q);
            handles.push(std::thread::spawn(move || {
                for i in 0..100 {
                    q.push(p * 100 + i).unwrap();
                }
            }));
        }
        let mut seen = Vec::new();
        for _ in 0..400 {
            seen.push(q.pop().unwrap());
        }
        for h in handles {
            h.join().unwrap();
        }
        seen.sort();
        assert_eq!(seen, (0..400).collect::<Vec<_>>());
    }
}
