//! Sessions (§2 "Sessions", §4.2 "Partial Execution").
//!
//! A Session owns the full graph (built once, extended as needed) and
//! serves `Run(inputs, output_names)` calls: it computes the transitive
//! closure needed for the requested outputs, rewrites feeds into `_Feed`
//! nodes and fetches into `_Fetch` nodes (Fig 6), places the pruned graph
//! over the device set, partitions it with Send/Recv pairs, compiles one
//! executor per partition, and runs them concurrently against a per-step
//! rendezvous. Compiled executables are cached per (feeds, fetches,
//! targets) signature — "most of our uses of TensorFlow set up a Session
//! with a graph once, and then execute the full graph or a few distinct
//! subgraphs thousands or millions of times."

use crate::device::DeviceSet;
use crate::error::{Result, Status};
use crate::executor::{CompiledGraph, Executor, RunContext};
use crate::graph::{AttrValue, Endpoint, Graph, Node, NodeId, TensorName};
use crate::kernels::StepState;
use crate::obs::profiler::Profiler;
use crate::partition::{partition, PartitionOptions, PartitionStats};
use crate::passes;
use crate::placement::{place, CostModel, PlacementStats};
use crate::rendezvous::{LocalRendezvous, Rendezvous};
use crate::resources::ResourceMgr;
use crate::tensor::Tensor;
use crate::tracing_tools::{StepStats, TraceCollector};
use std::collections::{BTreeMap, HashMap, HashSet};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex};

/// Session configuration.
#[derive(Clone)]
pub struct SessionOptions {
    pub devices: usize,
    /// Inter-op parallelism: threads per device dispatching ready nodes.
    pub threads_per_device: usize,
    /// Intra-op parallelism: lanes in each device's compute pool, which
    /// `parallel_for` fans a single large kernel out over (the OSDI'16
    /// inter-op/intra-op split). 1 ⇒ fully serial kernels. Results are
    /// bit-identical for every setting (the pool's determinism
    /// contract), and workers spawn lazily, so raising this only costs
    /// threads once a large kernel actually runs.
    pub intra_op_threads: usize,
    /// §5 build-time constant folding on pruned graphs.
    pub enable_constant_folding: bool,
    /// §5 arithmetic-identity simplification on pruned graphs.
    pub enable_arithmetic_simplification: bool,
    /// §5.1 CSE pass on pruned graphs.
    pub enable_cse: bool,
    /// §5 elementwise-chain fusion on pruned graphs.
    pub enable_elementwise_fusion: bool,
    /// §5.2 Recv scheduling pass on partitions.
    pub enable_recv_scheduling: bool,
    /// §5/§9 step memory planner (`crate::memory`): liveness-based arena
    /// buffer reuse and in-place kernel forwarding, planned once per
    /// cached step.
    pub enable_memory_planning: bool,
    pub partition: PartitionOptions,
    pub cost_model: CostModel,
    /// Collect §9.2 traces for every step.
    pub trace: bool,
    /// Continuous profiling: fold the last N steps' `StepStats` into the
    /// session's [`crate::obs::profiler::Profiler`] (rollups, step-latency
    /// percentiles, top-k reports for `/statusz`). 0 disables the
    /// profiler; any nonzero window implies per-step trace collection
    /// even when `trace` is off (the profiler is fed from spans).
    pub profile_window: usize,
}

impl Default for SessionOptions {
    fn default() -> Self {
        SessionOptions {
            devices: 1,
            threads_per_device: 2,
            intra_op_threads: 2,
            enable_constant_folding: true,
            enable_arithmetic_simplification: true,
            enable_cse: true,
            enable_elementwise_fusion: true,
            enable_recv_scheduling: true,
            enable_memory_planning: true,
            partition: PartitionOptions::default(),
            cost_model: CostModel::new(),
            trace: false,
            profile_window: 32,
        }
    }
}

/// Cached executable for one Run signature.
struct CachedStep {
    executors: Vec<Arc<CompiledGraph>>,
    /// Fetch names in caller order (keys into the step's fetch map).
    fetch_keys: Vec<String>,
    /// Feed rendezvous keys in caller order.
    feed_keys: Vec<String>,
    pub placement: PlacementStats,
    pub partition: PartitionStats,
    /// Per-pass reports from the §5 optimizer pipeline.
    pub optimizer: passes::PipelineStats,
}

/// Cache key for one Run signature. Feed *names* only — values vary per
/// call; shapes are deliberately not part of the signature, which is what
/// lets one cached step serve every batch size (see `crate::serving`,
/// whose lanes key on the same string so each lane mirrors one cache
/// entry). Components are length-prefixed so names containing the
/// separators cannot make two distinct signatures collide onto one entry
/// (`["a;b"]` vs `["a", "b"]`).
pub(crate) fn run_signature(feeds: &[&str], fetches: &[&str], targets: &[&str]) -> String {
    let mut s = String::new();
    let section = |names: &[&str], s: &mut String| {
        for k in names {
            s.push_str(&k.len().to_string());
            s.push(':');
            s.push_str(k);
            s.push(';');
        }
        s.push('|');
    };
    section(feeds, &mut s);
    section(fetches, &mut s);
    section(targets, &mut s);
    s
}

/// The client's handle to the runtime (§3 "client … uses the Session
/// interface to communicate with the master").
///
/// `Session` is `Send + Sync`: `run` takes `&self` and any number of
/// threads may call it concurrently (§7 Fig 9's concurrent-steps idiom,
/// and the substrate for `crate::serving`). Each call gets its own step
/// id, its own `StepState`, and its own per-step rendezvous, so concurrent
/// steps never observe each other's feeds or fetches; shared state
/// (variables in the `ResourceMgr`, queues) is deliberately cross-step.
pub struct Session {
    graph: Mutex<Graph>,
    devices: DeviceSet,
    resources: Arc<ResourceMgr>,
    options: SessionOptions,
    next_step: AtomicU64,
    cache: Mutex<HashMap<String, Arc<CachedStep>>>,
    /// Trace of the most recent traced step.
    last_trace: Mutex<Option<Arc<TraceCollector>>>,
    /// Per-node timings + arena deltas of the most recent traced step.
    last_step_stats: Mutex<Option<Arc<StepStats>>>,
    /// Continuous profiler fed every traced step (see
    /// `SessionOptions::profile_window`).
    profiler: Option<Arc<Profiler>>,
}

impl Session {
    pub fn new(graph: Graph, options: SessionOptions) -> Session {
        let devices = DeviceSet::local_with_intra_op(
            options.devices,
            options.threads_per_device,
            options.intra_op_threads,
        );
        Session::with_devices(graph, devices, options)
    }

    pub fn with_devices(graph: Graph, devices: DeviceSet, options: SessionOptions) -> Session {
        let profiler = match options.profile_window {
            0 => None,
            n => Some(Profiler::new(n)),
        };
        Session {
            graph: Mutex::new(graph),
            devices,
            resources: ResourceMgr::new(),
            options,
            next_step: AtomicU64::new(1),
            cache: Mutex::new(HashMap::new()),
            last_trace: Mutex::new(None),
            last_step_stats: Mutex::new(None),
            profiler,
        }
    }

    pub fn resources(&self) -> &Arc<ResourceMgr> {
        &self.resources
    }

    pub fn devices(&self) -> &DeviceSet {
        &self.devices
    }

    /// §2 "the Session interface supports an Extend method to augment the
    /// current graph". Invalidates cached executables. If `f` errors, the
    /// graph keeps whatever nodes `f` added before failing (harmless
    /// orphans — unreachable from any fetch) rather than being lost: the
    /// builder temporarily takes the graph out of the session, so it must
    /// be put back on every path.
    pub fn extend(&self, f: impl FnOnce(&mut crate::GraphBuilder) -> Result<()>) -> Result<()> {
        let mut graph = self.graph.lock().unwrap();
        let mut b = crate::GraphBuilder::new();
        b.graph = std::mem::take(&mut graph);
        let result = f(&mut b);
        *graph = b.graph;
        self.cache.lock().unwrap().clear();
        result
    }

    pub fn graph_snapshot(&self) -> Graph {
        self.graph.lock().unwrap().clone()
    }

    /// Run with no feeds/fetches, just target nodes (e.g. init ops).
    pub fn run_targets(&self, targets: &[&str]) -> Result<()> {
        self.run(&[], &[], targets)?;
        Ok(())
    }

    /// The paper's Run: feeds (name → tensor), fetches (name[:port]), and
    /// target nodes to run for effect. Returns fetched tensors in order.
    pub fn run(
        &self,
        feeds: &[(&str, Tensor)],
        fetches: &[&str],
        targets: &[&str],
    ) -> Result<Vec<Tensor>> {
        let feed_names: Vec<&str> = feeds.iter().map(|(k, _)| *k).collect();
        let signature = run_signature(&feed_names, fetches, targets);

        let cached = {
            let cache = self.cache.lock().unwrap();
            cache.get(&signature).cloned()
        };
        let cached = match cached {
            Some(c) => c,
            None => {
                // Compile outside the cache lock so a slow build does not
                // stall unrelated steps. Two threads racing on the same
                // new signature both compile; the first insert wins and
                // the loser adopts it, so later runs all share one entry.
                let built = Arc::new(self.build_step(feeds, fetches, targets)?);
                Arc::clone(
                    self.cache
                        .lock()
                        .unwrap()
                        .entry(signature)
                        .or_insert(built),
                )
            }
        };

        let step_id = self.next_step.fetch_add(1, Ordering::SeqCst);
        let step = StepState::new(step_id);
        let rendezvous: Arc<LocalRendezvous> = LocalRendezvous::new();
        // §4.2: feeds pre-populate the per-step rendezvous.
        for ((_, tensor), key) in feeds.iter().zip(&cached.feed_keys) {
            rendezvous.send(key, tensor.clone())?;
        }
        // The profiler is fed from the same spans as explicit tracing, so
        // a live profile window implies per-step collection too.
        let trace = if self.options.trace || self.profiler.is_some() {
            Some(TraceCollector::for_step("local", step_id))
        } else {
            None
        };
        // Arena counters are lifetime totals shared across runs; snapshot
        // them now so the traced step can report its *delta*.
        let mem_before: Vec<crate::memory::MemSnapshot> = if trace.is_some() {
            cached
                .executors
                .iter()
                .map(|cg| {
                    cg.arena_pool.as_ref().map(|p| p.counters().snapshot()).unwrap_or_default()
                })
                .collect()
        } else {
            Vec::new()
        };

        // One executor per partition, running concurrently (§3.2.2: node
        // scheduling is decentralized into the per-device executors).
        // Perf (§Perf L3 iteration 1): the single-partition fast path runs
        // on the caller thread — a per-step thread spawn cost ~12µs of the
        // 36µs empty-step overhead.
        let errors: Vec<Status> = if cached.executors.len() == 1 {
            let ctx = RunContext {
                resources: Arc::clone(&self.resources),
                rendezvous: rendezvous.clone() as Arc<dyn Rendezvous>,
                step: Arc::clone(&step),
                trace: trace.clone(),
            };
            Executor::new(Arc::clone(&cached.executors[0])).run(ctx).err().into_iter().collect()
        } else {
            std::thread::scope(|scope| {
                let mut handles = Vec::new();
                for cg in &cached.executors {
                    let rendezvous: Arc<dyn Rendezvous> = rendezvous.clone();
                    let ctx = RunContext {
                        resources: Arc::clone(&self.resources),
                        rendezvous,
                        step: Arc::clone(&step),
                        trace: trace.clone(),
                    };
                    let cg = Arc::clone(cg);
                    handles.push(scope.spawn(move || Executor::new(cg).run(ctx)));
                }
                handles
                    .into_iter()
                    .filter_map(|h| h.join().expect("executor thread panicked").err())
                    .collect()
            })
        };
        if let Some(t) = trace {
            let memory = cached
                .executors
                .iter()
                .zip(&mem_before)
                .map(|(cg, before)| crate::memory::MemoryReport {
                    device: cg.device.name(),
                    plan: cg.plan.as_ref().map(|p| p.stats.clone()).unwrap_or_default(),
                    runtime: cg
                        .arena_pool
                        .as_ref()
                        .map(|p| p.counters().snapshot())
                        .unwrap_or_default()
                        .delta_since(before),
                    high_water: cg
                        .arena_pool
                        .as_ref()
                        .map(|p| p.counters().high_water())
                        .unwrap_or_default(),
                })
                .collect();
            let stats = Arc::new(StepStats::from_events(step_id, &t.events(), memory));
            if let Some(p) = &self.profiler {
                p.observe(Arc::clone(&stats));
            }
            *self.last_step_stats.lock().unwrap() = Some(stats);
            *self.last_trace.lock().unwrap() = Some(t);
        }
        if let Some(e) = errors.into_iter().next() {
            return Err(e);
        }

        let mut fetched = step.take_fetches();
        cached
            .fetch_keys
            .iter()
            .map(|k| {
                fetched
                    .remove(k)
                    .ok_or_else(|| Status::internal(format!("fetch {k:?} was not produced")))
            })
            .collect()
    }

    /// Trace of the most recent run (when `options.trace`).
    pub fn last_trace(&self) -> Option<Arc<TraceCollector>> {
        self.last_trace.lock().unwrap().clone()
    }

    /// Per-node accumulated timings and per-step arena deltas of the most
    /// recent run (when `options.trace`) — the profile
    /// [`crate::placement::CostModel::update_from_step_stats`] consumes,
    /// persistable via [`StepStats::to_json`].
    pub fn last_step_stats(&self) -> Option<Arc<StepStats>> {
        self.last_step_stats.lock().unwrap().clone()
    }

    /// Per-pass optimizer reports of the cached step for a signature (the
    /// §5 ablation benches and equivalence tests read these).
    pub fn optimizer_stats(
        &self,
        feeds: &[&str],
        fetches: &[&str],
        targets: &[&str],
    ) -> Option<passes::PipelineStats> {
        self.cache
            .lock()
            .unwrap()
            .get(&run_signature(feeds, fetches, targets))
            .map(|c| c.optimizer.clone())
    }

    /// Memory reports of the cached step for a signature, one per
    /// partition executor: the build-time `MemoryPlanStats` beside the
    /// runtime arena counters accumulated over every run so far. `None`
    /// when the signature is not cached; empty plan stats when
    /// `enable_memory_planning` is off.
    pub fn memory_stats(
        &self,
        feeds: &[&str],
        fetches: &[&str],
        targets: &[&str],
    ) -> Option<Vec<crate::memory::MemoryReport>> {
        self.cache
            .lock()
            .unwrap()
            .get(&run_signature(feeds, fetches, targets))
            .map(|c| {
                c.executors
                    .iter()
                    .map(|cg| crate::memory::MemoryReport {
                        device: cg.device.name(),
                        plan: cg.plan.as_ref().map(|p| p.stats.clone()).unwrap_or_default(),
                        runtime: cg
                            .arena_pool
                            .as_ref()
                            .map(|p| p.counters().snapshot())
                            .unwrap_or_default(),
                        high_water: cg
                            .arena_pool
                            .as_ref()
                            .map(|p| p.counters().high_water())
                            .unwrap_or_default(),
                    })
                    .collect()
            })
    }

    /// The session's continuous profiler (`None` when
    /// `SessionOptions::profile_window` is 0). `/statusz` renders its
    /// report; `memory_profile` complements it with arena watermarks.
    pub fn profiler(&self) -> Option<&Arc<Profiler>> {
        self.profiler.as_ref()
    }

    /// Memory attribution across *every* cached step signature: one
    /// `MemoryReport` per partition executor per cached step, each
    /// carrying the plan stats, lifetime arena counters, and the
    /// per-step byte high-watermark.
    pub fn memory_profile(&self) -> Vec<crate::memory::MemoryReport> {
        self.cache
            .lock()
            .unwrap()
            .values()
            .flat_map(|c| {
                c.executors
                    .iter()
                    .map(|cg| crate::memory::MemoryReport {
                        device: cg.device.name(),
                        plan: cg.plan.as_ref().map(|p| p.stats.clone()).unwrap_or_default(),
                        runtime: cg
                            .arena_pool
                            .as_ref()
                            .map(|p| p.counters().snapshot())
                            .unwrap_or_default(),
                        high_water: cg
                            .arena_pool
                            .as_ref()
                            .map(|p| p.counters().high_water())
                            .unwrap_or_default(),
                    })
                    .collect::<Vec<_>>()
            })
            .collect()
    }

    /// Stats of the cached step for a signature (experiments use this).
    pub fn step_stats(
        &self,
        feeds: &[&str],
        fetches: &[&str],
        targets: &[&str],
    ) -> Option<(PlacementStats, PartitionStats)> {
        self.cache
            .lock()
            .unwrap()
            .get(&run_signature(feeds, fetches, targets))
            .map(|c| (c.placement.clone(), c.partition.clone()))
    }

    /// Build (prune → rewrite feeds/fetches → optimizer pipeline → place →
    /// partition → schedule → compile) one step. The optimizer pipeline is
    /// `passes::PassManager::standard` (fold → simplify → cse → fuse), with
    /// each pass gated by its `SessionOptions` flag.
    fn build_step(
        &self,
        feeds: &[(&str, Tensor)],
        fetches: &[&str],
        targets: &[&str],
    ) -> Result<CachedStep> {
        let full = self.graph.lock().unwrap().clone();
        let feed_names: Vec<&str> = feeds.iter().map(|(k, _)| *k).collect();
        let (pruned, feed_keys, fetch_keys) = prune_for_run(&full, &feed_names, fetches, targets)?;

        let pipeline = passes::PassManager::standard(
            self.options.enable_constant_folding,
            self.options.enable_arithmetic_simplification,
            self.options.enable_cse,
            self.options.enable_elementwise_fusion,
        );
        let (pruned, optimizer) = pipeline.run(&pruned)?;

        let mut placed = pruned;
        let placement = place(&mut placed, &self.devices, &self.options.cost_model)?;
        let (mut parts, partition_stats) = partition(&placed, &self.options.partition, "")?;

        if self.options.enable_recv_scheduling {
            passes::schedule_recvs_global(&mut parts, &self.options.cost_model)?;
        }

        let executors = parts
            .into_iter()
            .map(|p| {
                let device = self.devices.find_by_name(&p.device)?;
                CompiledGraph::compile_planned(
                    &p.graph,
                    device,
                    self.options.enable_memory_planning,
                )
            })
            .collect::<Result<Vec<_>>>()?;

        Ok(CachedStep {
            executors,
            fetch_keys,
            feed_keys,
            placement,
            partition: partition_stats,
            optimizer,
        })
    }
}

/// §4.2 graph transformation (Fig 6): replace each fed endpoint with a
/// `_Feed` node, attach a `_Fetch` node to each fetched endpoint, then
/// keep only nodes reachable (backwards) from fetches+targets.
/// Returns (rewritten graph, feed rendezvous keys, fetch map keys).
pub fn prune_for_run(
    graph: &Graph,
    feeds: &[&str],
    fetches: &[&str],
    targets: &[&str],
) -> Result<(Graph, Vec<String>, Vec<String>)> {
    let mut g = graph.clone();

    // Feeds: add _Feed nodes and redirect consumers of the fed endpoint.
    let mut feed_keys = Vec::with_capacity(feeds.len());
    for name in feeds {
        let tn = TensorName::parse(name)?;
        let src = g.must_find(&tn.node)?;
        let key = format!("feed;{tn}");
        feed_keys.push(key.clone());
        let feed_name = g.unique_name(&format!("_feed/{tn}"));
        let feed_id = g.add(Node {
            name: feed_name,
            op: "_Feed".into(),
            inputs: vec![],
            control_inputs: vec![],
            attrs: {
                let mut a = BTreeMap::new();
                a.insert("key".to_string(), AttrValue::Str(key));
                // Carry the fed endpoint's declared dtype: build-time
                // passes (simplify's dtype guard) read it; kernels don't.
                if let Some(t) = g.node(src).attr_opt("T") {
                    a.insert("T".to_string(), t.clone());
                }
                a
            },
            requested_device: g.node(src).requested_device.clone(),
            assigned_device: None,
        })?;
        // Redirect all consumers of (src, port) to the feed node.
        for id in g.ids().collect::<Vec<_>>() {
            if id == feed_id {
                continue;
            }
            let node = g.node_mut(id);
            for e in &mut node.inputs {
                if e.node == src && e.port == tn.port {
                    *e = Endpoint::new(feed_id, 0);
                }
            }
        }
    }

    // Fetches: attach _Fetch sinks.
    let mut fetch_keys = Vec::with_capacity(fetches.len());
    let mut roots: Vec<NodeId> = Vec::new();
    for name in fetches {
        let tn = TensorName::parse(name)?;
        // A fetch of a fed tensor reads the feed node (§4.2 allows both).
        let (src_id, src_port) = match feeds.iter().position(|f| {
            TensorName::parse(f).map(|ftn| ftn == tn).unwrap_or(false)
        }) {
            Some(_) => {
                let feed_node = g
                    .find(&format!("_feed/{tn}"))
                    .ok_or_else(|| Status::internal("feed node missing"))?;
                (feed_node, 0)
            }
            None => (g.must_find(&tn.node)?, tn.port),
        };
        let key = tn.to_string();
        fetch_keys.push(key.clone());
        let fetch_name = g.unique_name(&format!("_fetch/{tn}"));
        let fetch_id = g.add(Node {
            name: fetch_name,
            op: "_Fetch".into(),
            inputs: vec![Endpoint::new(src_id, src_port)],
            control_inputs: vec![],
            attrs: {
                let mut a = BTreeMap::new();
                a.insert("name".to_string(), AttrValue::Str(key));
                a
            },
            requested_device: String::new(),
            assigned_device: None,
        })?;
        roots.push(fetch_id);
    }
    for t in targets {
        roots.push(g.must_find(t)?);
    }
    if roots.is_empty() {
        return Err(Status::invalid_argument("Run() needs at least one fetch or target"));
    }

    // §2: "compute the transitive closure of all nodes that must be
    // executed"; drop everything else (Fig 6: nodes d and e are not run).
    let keep: HashSet<NodeId> = g.reachable_from(&roots);
    let (sub, _) = g.subgraph(&keep);
    Ok((sub, feed_keys, fetch_keys))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ops::builder::GraphBuilder;
    use crate::tensor::{DType, Tensor};

    fn session_of(b: GraphBuilder, devices: usize) -> Session {
        Session::new(
            b.into_graph(),
            SessionOptions { devices, ..Default::default() },
        )
    }

    #[test]
    fn run_constant_graph() {
        let mut b = GraphBuilder::new();
        let x = b.scalar(2.0);
        let y = b.scalar(3.0);
        let z = b.mul(x, y);
        let zname = b.graph.node(z.node).name.clone();
        let sess = session_of(b, 1);
        let out = sess.run(&[], &[&zname], &[]).unwrap();
        assert_eq!(out[0].scalar_value_f32().unwrap(), 6.0);
    }

    #[test]
    fn feed_and_fetch() {
        let mut b = GraphBuilder::new();
        let x = b.placeholder("x", DType::F32).unwrap();
        let two = b.scalar(2.0);
        let y = b.mul(x, two);
        let yname = b.graph.node(y.node).name.clone();
        let sess = session_of(b, 1);
        let out = sess
            .run(&[("x", Tensor::scalar_f32(21.0))], &[&yname], &[])
            .unwrap();
        assert_eq!(out[0].scalar_value_f32().unwrap(), 42.0);
        // Different feed value, same cached executable.
        let out2 = sess
            .run(&[("x", Tensor::scalar_f32(5.0))], &[&yname], &[])
            .unwrap();
        assert_eq!(out2[0].scalar_value_f32().unwrap(), 10.0);
    }

    #[test]
    fn unfed_placeholder_errors() {
        let mut b = GraphBuilder::new();
        let x = b.placeholder("x", DType::F32).unwrap();
        let y = b.neg(x);
        let yname = b.graph.node(y.node).name.clone();
        let sess = session_of(b, 1);
        assert!(sess.run(&[], &[&yname], &[]).is_err());
    }

    #[test]
    fn figure6_partial_execution_prunes() {
        // Fig 6: a -> {b? no: graph is a→b→f? } Build the paper's shape:
        // feed b, fetch f; d and e must not execute.
        let mut b = GraphBuilder::new();
        let a = b.placeholder("a", DType::F32).unwrap();
        let bb = b.op1("Neg", "b", vec![a], vec![]).unwrap();
        let _c = b.op1("Neg", "c", vec![bb], vec![]).unwrap();
        let f = b.op1("Square", "f", vec![bb], vec![]).unwrap();
        // d, e: a separate branch (would fail if executed — Placeholder).
        let d = b.placeholder("d", DType::F32).unwrap();
        let _e = b.op1("Neg", "e", vec![d], vec![]).unwrap();
        let fname = b.graph.node(f.node).name.clone();
        let (pruned, _, _) =
            prune_for_run(&b.graph, &["b"], &[&format!("{fname}:0")], &[]).unwrap();
        // d and e are pruned away.
        assert!(pruned.find("d").is_none());
        assert!(pruned.find("e").is_none());
        // The original producer of b (Neg over a) is also unnecessary: b is fed.
        assert!(pruned.find("a").is_none());
        // And the graph runs end to end with b fed.
        let sess = session_of(b, 1);
        let out = sess.run(&[("b", Tensor::scalar_f32(3.0))], &[&fname], &[]).unwrap();
        assert_eq!(out[0].scalar_value_f32().unwrap(), 9.0);
    }

    #[test]
    fn variables_persist_across_runs() {
        let mut b = GraphBuilder::new();
        let v = b.variable("counter", Tensor::scalar_f32(0.0)).unwrap();
        let one = b.scalar(1.0);
        let inc = b.assign_add(v, one).unwrap();
        let init_name = b.graph.node(b.init_ops[0]).name.clone();
        let inc_name = b.graph.node(inc).name.clone();
        let sess = session_of(b, 1);
        sess.run_targets(&[&init_name]).unwrap();
        for _ in 0..5 {
            sess.run_targets(&[&inc_name]).unwrap();
        }
        let out = sess.run(&[], &["counter"], &[]).unwrap();
        assert_eq!(out[0].scalar_value_f32().unwrap(), 5.0);
    }

    #[test]
    fn uninitialized_variable_read_fails() {
        let mut b = GraphBuilder::new();
        b.variable("v", Tensor::scalar_f32(1.0)).unwrap();
        let sess = session_of(b, 1);
        let e = sess.run(&[], &["v"], &[]).unwrap_err();
        assert_eq!(e.code, crate::error::Code::FailedPrecondition);
    }

    #[test]
    fn figure1_program_end_to_end() {
        // The paper's Fig 1 program: relu(W x + b), run 10 times.
        let mut b = GraphBuilder::new();
        let w = b.variable_uniform("W", vec![100, 784], -1.0, 1.0, 42).unwrap();
        let bias = b.variable("b", Tensor::zeros(DType::F32, vec![100, 1]).unwrap()).unwrap();
        let x = b.placeholder("x", DType::F32).unwrap();
        let wx = b.matmul(w, x);
        let pre = b.add(wx, bias);
        let relu = b.relu(pre);
        let relu_name = b.graph.node(relu.node).name.clone();
        let inits: Vec<String> =
            b.init_ops.iter().map(|&i| b.graph.node(i).name.clone()).collect();
        let sess = session_of(b, 1);
        sess.run_targets(&inits.iter().map(|s| s.as_str()).collect::<Vec<_>>()).unwrap();
        for step in 0..10 {
            let input = Tensor::fill_f32(vec![784, 1], 0.01 * (step as f32 + 1.0));
            let out = sess.run(&[("x", input)], &[&relu_name], &[]).unwrap();
            assert_eq!(out[0].shape().dims(), &[100, 1]);
            assert!(out[0].as_f32().unwrap().iter().all(|&v| v >= 0.0), "relu output negative");
        }
    }

    #[test]
    fn multi_device_run_matches_single_device() {
        // Same graph on 1 and 3 devices must agree (§3.2 correctness).
        let build = || {
            let mut b = GraphBuilder::new();
            let data: Vec<f32> = (0..16).map(|i| i as f32 * 0.1).collect();
            let x = b.constant(Tensor::from_f32(vec![4, 4], data).unwrap());
            let mut l = x;
            let mut r = x;
            for _ in 0..3 {
                l = b.matmul(l, l);
                r = b.matmul(r, x);
            }
            let out = b.add(l, r);
            let name = b.graph.node(out.node).name.clone();
            (b, name)
        };
        let (b1, n1) = build();
        let s1 = session_of(b1, 1);
        let r1 = s1.run(&[], &[&n1], &[]).unwrap();
        let (b3, n3) = build();
        let s3 = session_of(b3, 3);
        let r3 = s3.run(&[], &[&n3], &[]).unwrap();
        assert!(r1[0].allclose(&r3[0], 1e-4, 1e-4));
    }

    #[test]
    fn while_loop_executes() {
        // while (i < 10) i += 1 → exit value 10.
        let mut b = GraphBuilder::new();
        let zero = b.scalar(0.0);
        let exits = b
            .while_loop(
                "loop",
                vec![zero],
                |b, v| {
                    let lim = b.scalar(10.0);
                    Ok(b.less(v[0], lim))
                },
                |b, v| {
                    let one = b.scalar(1.0);
                    Ok(vec![b.add(v[0], one)])
                },
            )
            .unwrap();
        let name = format!("{}:0", b.graph.node(exits[0].node).name);
        let sess = session_of(b, 1);
        let out = sess.run(&[], &[&name], &[]).unwrap();
        assert_eq!(out[0].scalar_value_f32().unwrap(), 10.0);
    }

    #[test]
    fn conditional_via_switch_merge() {
        // if pred: x*10 else x+1, as Switch/Merge (§4.4).
        for (pred, expect) in [(true, 50.0f32), (false, 6.0)] {
            let mut b = GraphBuilder::new();
            let x = b.scalar(5.0);
            let p = b.constant(Tensor::scalar_bool(pred));
            let (f_side, t_side) = b.switch(x, p).unwrap();
            let ten = b.scalar(10.0);
            let one = b.scalar(1.0);
            let t_out = b.mul(t_side, ten);
            let f_out = b.add(f_side, one);
            let (merged, _) = b.merge(vec![f_out, t_out]).unwrap();
            let name = format!("{}:0", b.graph.node(merged.node).name);
            let sess = session_of(b, 1);
            let out = sess.run(&[], &[&name], &[]).unwrap();
            assert_eq!(out[0].scalar_value_f32().unwrap(), expect, "pred={pred}");
        }
    }

    #[test]
    fn error_in_kernel_propagates() {
        let mut b = GraphBuilder::new();
        let x = b.constant(Tensor::from_f32(vec![1], vec![f32::NAN]).unwrap());
        let checked = b.op1("CheckNumerics", "check", vec![x], vec![]).unwrap();
        let name = b.graph.node(checked.node).name.clone();
        let sess = session_of(b, 1);
        let e = sess.run(&[], &[&name], &[]).unwrap_err();
        assert_eq!(e.code, crate::error::Code::InvalidArgument);
        assert!(e.message.contains("CheckNumerics"));
    }

    #[test]
    fn extend_adds_nodes() {
        let mut b = GraphBuilder::new();
        let x = b.scalar(4.0);
        let xname = b.graph.node(x.node).name.clone();
        let sess = session_of(b, 1);
        sess.extend(|b| {
            let x = crate::graph::Endpoint::new(b.graph.must_find(&xname)?, 0);
            let y = b.sqrt(x);
            let _ = y;
            Ok(())
        })
        .unwrap();
        let out = sess.run(&[], &["Sqrt"], &[]).unwrap();
        assert_eq!(out[0].scalar_value_f32().unwrap(), 2.0);
    }

    #[test]
    fn queue_roundtrip_through_session() {
        let mut b = GraphBuilder::new();
        let q = b
            .op1(
                "FIFOQueue",
                "q",
                vec![],
                vec![
                    ("capacity", AttrValue::I64(8)),
                    ("component_types", AttrValue::ListType(vec![DType::F32])),
                ],
            )
            .unwrap();
        let val = b.scalar(7.5);
        let enq = b.op("Enqueue", "enq", vec![q, val], vec![]).unwrap();
        let deq = b
            .op(
                "Dequeue",
                "deq",
                vec![q],
                vec![("component_types", AttrValue::ListType(vec![DType::F32]))],
            )
            .unwrap();
        let enq_name = b.graph.node(enq).name.clone();
        let deq_name = format!("{}:0", b.graph.node(deq).name);
        let sess = session_of(b, 1);
        sess.run_targets(&[&enq_name]).unwrap();
        let out = sess.run(&[], &[&deq_name], &[]).unwrap();
        assert_eq!(out[0].scalar_value_f32().unwrap(), 7.5);
    }

    #[test]
    fn optimizer_pipeline_runs_in_build_step() {
        let mut b = GraphBuilder::new();
        let x = b.placeholder("x", DType::F32).unwrap();
        let c1 = b.scalar(2.0);
        let c2 = b.scalar(3.0);
        let c = b.mul(c1, c2); // folds to 6
        let one = b.scalar(1.0);
        let m = b.mul(x, one); // simplifies to x
        let a = b.add(m, c);
        let t = b.tanh(a); // Add→Tanh fuses
        let name = format!("{}:0", b.graph.node(t.node).name);
        let sess = session_of(b, 1);
        let out = sess.run(&[("x", Tensor::scalar_f32(4.0))], &[&name], &[]).unwrap();
        assert!((out[0].scalar_value_f32().unwrap() - 10.0f32.tanh()).abs() < 1e-6);
        let stats = sess.optimizer_stats(&["x"], &[&name], &[]).unwrap();
        assert!(stats.report("constant_folding").unwrap().rewrites >= 1);
        assert!(stats.report("arithmetic_simplification").unwrap().rewrites >= 1);
        assert!(stats.report("elementwise_fusion").unwrap().rewrites >= 1);
    }

    #[test]
    fn optimizer_disabled_matches_enabled() {
        let build = || {
            let mut b = GraphBuilder::new();
            let x = b.placeholder("x", DType::F32).unwrap();
            let zero = b.scalar(0.0);
            let c = b.scalar(2.5);
            let cc = b.mul(c, c);
            let s = b.add(x, zero);
            let m = b.mul(s, cc);
            let t = b.tanh(m);
            let name = format!("{}:0", b.graph.node(t.node).name);
            (b, name)
        };
        let run = |opts: SessionOptions| {
            let (b, name) = build();
            Session::new(b.into_graph(), opts)
                .run(
                    &[("x", Tensor::from_f32(vec![3], vec![0.5, -1.0, 2.0]).unwrap())],
                    &[&name],
                    &[],
                )
                .unwrap()
                .remove(0)
        };
        let on = run(SessionOptions::default());
        let off = run(SessionOptions {
            enable_constant_folding: false,
            enable_arithmetic_simplification: false,
            enable_cse: false,
            enable_elementwise_fusion: false,
            ..Default::default()
        });
        assert!(on.allclose(&off, 1e-6, 1e-6));
    }

    #[test]
    fn trace_collected_when_enabled() {
        let mut b = GraphBuilder::new();
        let x = b.scalar(1.0);
        let y = b.neg(x);
        let name = b.graph.node(y.node).name.clone();
        let sess = Session::new(
            b.into_graph(),
            SessionOptions { trace: true, ..Default::default() },
        );
        sess.run(&[], &[&name], &[]).unwrap();
        let t = sess.last_trace().unwrap();
        assert!(!t.is_empty());
        // Every event carries the run's step id, and the run distilled a
        // StepStats with the traced node in it.
        let ss = sess.last_step_stats().unwrap();
        assert!(t.events().iter().all(|e| e.step == ss.step_id));
        assert!(ss.node(&name).is_some(), "fetched node profiled: {:?}", ss.nodes);
        assert!(!ss.memory.is_empty(), "one memory report per executor");
        // Roundtrips through its JSON persistence form.
        let back = crate::tracing_tools::StepStats::from_json(&ss.to_json()).unwrap();
        assert_eq!(back.nodes, ss.nodes);
        // A second traced run replaces the profile with the new step id.
        sess.run(&[], &[&name], &[]).unwrap();
        assert!(sess.last_step_stats().unwrap().step_id > ss.step_id);
    }

    #[test]
    fn profiler_window_feeds_without_explicit_trace() {
        let mut b = GraphBuilder::new();
        let x = b.scalar(2.0);
        let y = b.neg(x);
        let name = b.graph.node(y.node).name.clone();
        // trace: false, but a nonzero profile_window still collects
        // per-step stats — the continuous-profiling contract.
        let sess = Session::new(
            b.into_graph(),
            SessionOptions { trace: false, profile_window: 4, ..Default::default() },
        );
        for _ in 0..6 {
            sess.run(&[], &[&name], &[]).unwrap();
        }
        let p = sess.profiler().expect("profiler on when profile_window > 0");
        assert_eq!(p.steps_observed(), 6);
        assert_eq!(p.window_len(), 4, "ring bounded by the window");
        let rollups = p.node_rollups();
        assert!(
            rollups.iter().any(|r| r.name == name),
            "fetched node rolled up: {rollups:?}"
        );
        assert!(!sess.memory_profile().is_empty(), "memory attribution per executor");
        assert!(p.report_text(5).contains(&name), "{}", p.report_text(5));
    }
}
