//! §5 constant folding: find maximal Const-rooted subgraphs of the pruned
//! step graph and evaluate them at build time — reusing the existing
//! single-device executor as the evaluator, so a folded value is computed
//! by exactly the kernels that would have computed it at step time — then
//! replace each folded endpoint with a `Const` node.
//!
//! A node is *foldable* when it is pure (stateless, non-control-flow,
//! non-internal), has a CPU kernel, carries no control edges in either
//! direction, and every data input is foldable. `Const` is the base case.
//! Control-flow ops are never foldable, so folding cannot cross a
//! `Switch`/`Merge` boundary — dead branches stay dead and are never
//! evaluated at build time (the §4.4 tests pin this down).
//!
//! Fail-open contract: if build-time evaluation errors (a kernel that
//! would also error at step time, e.g. a divide in an op that rejects the
//! value), the graph is returned unchanged and the error surfaces at run
//! time exactly as without the pass.

use crate::device::{Device, DeviceSpec};
use crate::error::Result;
use crate::executor::{CompiledGraph, Executor, RunContext};
use crate::graph::{AttrValue, Endpoint, Graph, Node, NodeId};
use crate::kernels::{has_kernel, StepState};
use crate::ops::{self, Category};
use crate::rendezvous::{LocalRendezvous, Rendezvous};
use crate::resources::ResourceMgr;
use std::collections::{BTreeMap, HashMap, HashSet};
use std::sync::Arc;

/// Statistics from one constant-folding run.
#[derive(Debug, Default, Clone, PartialEq)]
pub struct FoldStats {
    pub nodes_before: usize,
    /// (node, port) endpoints replaced by Const nodes.
    pub endpoints_folded: usize,
    /// Nodes dropped because every consumer now reads a folded Const.
    pub nodes_removed: usize,
    /// Endpoints evaluated but left in place (result over the size cap).
    pub skipped_large: usize,
}

/// Folded tensors above this size stay unmaterialized: baking a huge
/// literal into the graph trades step-time compute for resident memory
/// and serialized-graph bloat.
pub const MAX_FOLDED_BYTES: usize = 8 << 20;

/// Ops that are registered stateless but must not be folded anyway:
/// `Shuffle` keeps RNG state inside its kernel instance (folding would
/// freeze one permutation), and `CheckNumerics` exists to fail at step
/// time with a step-time message.
fn denylisted(op: &str) -> bool {
    matches!(op, "Shuffle" | "CheckNumerics")
}

fn fold_key(i: usize) -> String {
    format!("fold:{i}")
}

/// Run constant folding over `graph`. Pure graph→graph; see module docs.
pub fn constant_folding(graph: &Graph) -> Result<(Graph, FoldStats)> {
    let mut stats = FoldStats { nodes_before: graph.len(), ..Default::default() };
    let order = graph.topo_order()?;
    let fanout = graph.fanout();

    // ---- foldability (propagates forward from Const roots) --------------
    let mut foldable = vec![false; graph.len()];
    for &id in &order {
        let n = graph.node(id);
        let def = match ops::lookup(&n.op) {
            Ok(d) => d,
            Err(_) => continue, // unknown op: not foldable
        };
        let pure = !def.stateful
            && !matches!(
                def.category,
                Category::ControlFlow
                    | Category::Internal
                    | Category::QueueSync
                    | Category::Checkpointing
            )
            && n.op != "Placeholder"
            && !n.op.starts_with('_')
            && !denylisted(&n.op)
            && has_kernel(&n.op, "cpu")
            && n.control_inputs.is_empty()
            && fanout.control[id.0].is_empty();
        foldable[id.0] = pure && n.inputs.iter().all(|e| foldable[e.node.0]);
    }

    // ---- fold frontier: foldable endpoints read by non-foldable nodes ---
    let mut frontier: Vec<(NodeId, usize)> = Vec::new();
    let mut seen: HashSet<(NodeId, usize)> = HashSet::new();
    for id in graph.ids() {
        if !foldable[id.0] || graph.node(id).op == "Const" {
            continue;
        }
        for &(consumer, slot) in &fanout.data[id.0] {
            if foldable[consumer.0] {
                continue;
            }
            let port = graph.node(consumer).inputs[slot].port;
            if seen.insert((id, port)) {
                frontier.push((id, port));
            }
        }
    }
    if frontier.is_empty() {
        return Ok((graph.clone(), stats));
    }

    // ---- build the evaluation graph: the foldable closure + fetches -----
    let mut needed: HashSet<NodeId> = HashSet::new();
    let mut stack: Vec<NodeId> = frontier.iter().map(|&(id, _)| id).collect();
    while let Some(id) = stack.pop() {
        if !needed.insert(id) {
            continue;
        }
        for e in &graph.node(id).inputs {
            stack.push(e.node);
        }
    }
    // Topological order so every input is materialized before its consumer
    // (plain id order is not guaranteed backward-referencing).
    let mut eval = Graph::new();
    let mut remap: HashMap<NodeId, NodeId> = HashMap::new();
    for &id in &order {
        if !needed.contains(&id) {
            continue;
        }
        let old = graph.node(id);
        let node = Node {
            name: old.name.clone(),
            op: old.op.clone(),
            inputs: old.inputs.iter().map(|e| Endpoint::new(remap[&e.node], e.port)).collect(),
            control_inputs: vec![],
            attrs: old.attrs.clone(),
            requested_device: String::new(),
            assigned_device: None,
        };
        remap.insert(id, eval.add(node)?);
    }
    for (i, &(id, port)) in frontier.iter().enumerate() {
        let mut attrs = BTreeMap::new();
        attrs.insert("name".to_string(), AttrValue::Str(fold_key(i)));
        eval.add(Node {
            name: format!("_fold_fetch_{i}"),
            op: "_Fetch".into(),
            inputs: vec![Endpoint::new(remap[&id], port)],
            control_inputs: vec![],
            attrs,
            requested_device: String::new(),
            assigned_device: None,
        })?;
    }

    // ---- evaluate with the single-device executor ------------------------
    let device = Arc::new(Device::new(DeviceSpec::local_cpu(0), 1));
    let compiled = match CompiledGraph::compile(&eval, device) {
        Ok(c) => c,
        Err(_) => return Ok((graph.clone(), stats)), // fail open
    };
    let step = StepState::new(0);
    let ctx = RunContext {
        resources: ResourceMgr::new(),
        rendezvous: LocalRendezvous::new() as Arc<dyn Rendezvous>,
        step: Arc::clone(&step),
        trace: None,
    };
    if Executor::new(compiled).run(ctx).is_err() {
        return Ok((graph.clone(), stats)); // fail open: error surfaces at run time
    }
    let mut values = step.take_fetches();

    // ---- substitute Const nodes and redirect consumers -------------------
    let mut rewritten = graph.clone();
    let mut replacement: HashMap<(NodeId, usize), NodeId> = HashMap::new();
    for (i, &(id, port)) in frontier.iter().enumerate() {
        let Some(value) = values.remove(&fold_key(i)) else { continue };
        if value.size_bytes() > MAX_FOLDED_BYTES {
            stats.skipped_large += 1;
            continue;
        }
        let name = rewritten.unique_name(&format!("{}/folded_{port}", graph.node(id).name));
        let dtype = value.dtype();
        let mut attrs = BTreeMap::new();
        attrs.insert("value".to_string(), AttrValue::Tensor(value));
        attrs.insert("T".to_string(), AttrValue::Type(dtype));
        let const_id = rewritten.add(Node {
            name,
            op: "Const".into(),
            inputs: vec![],
            control_inputs: vec![],
            attrs,
            requested_device: graph.node(id).requested_device.clone(),
            assigned_device: None,
        })?;
        replacement.insert((id, port), const_id);
        stats.endpoints_folded += 1;
    }
    if replacement.is_empty() {
        return Ok((graph.clone(), stats));
    }
    for cid in graph.ids() {
        if foldable[cid.0] {
            continue; // only non-foldable consumers are redirected
        }
        let new_inputs: Vec<Endpoint> = rewritten
            .node(cid)
            .inputs
            .iter()
            .map(|e| match replacement.get(&(e.node, e.port)) {
                Some(&c) => Endpoint::new(c, 0),
                None => *e,
            })
            .collect();
        rewritten.node_mut(cid).inputs = new_inputs;
    }

    // ---- prune foldable nodes nothing reads anymore ----------------------
    // Roots: every non-foldable node (they can only lose edges *into* the
    // foldable set, never consumers), the new Consts, and foldable nodes
    // with no consumers at all (pure targets run for their own sake).
    let mut roots: Vec<NodeId> = Vec::new();
    for id in graph.ids() {
        let sink = fanout.data[id.0].is_empty() && fanout.control[id.0].is_empty();
        if !foldable[id.0] || sink {
            roots.push(id);
        }
    }
    roots.extend((graph.len()..rewritten.len()).map(NodeId));
    let keep = rewritten.reachable_from(&roots);
    stats.nodes_removed = rewritten.len() - keep.len();
    let (out, _) = rewritten.subgraph(&keep);
    Ok((out, stats))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ops::builder::GraphBuilder;
    use crate::tensor::{DType, Tensor};

    #[test]
    fn folds_const_subgraph_feeding_placeholder_math() {
        let mut b = GraphBuilder::new();
        let x = b.placeholder("x", DType::F32).unwrap();
        let two = b.scalar(2.0);
        let three = b.scalar(3.0);
        let six = b.mul(two, three);
        let neg = b.neg(six);
        let _y = b.add(x, neg); // frontier: Neg's output feeds Add via x-side graph
        let (g, stats) = constant_folding(&b.graph).unwrap();
        assert_eq!(stats.endpoints_folded, 1);
        // Mul and Neg (and the now-dead consts) are gone; a folded Const
        // carrying -6 feeds Add.
        assert!(g.nodes.iter().all(|n| n.op != "Mul" && n.op != "Neg"));
        let add = g.nodes.iter().find(|n| n.op == "Add").unwrap();
        let folded = g.node(add.inputs[1].node);
        assert_eq!(folded.op, "Const");
        assert_eq!(
            folded.attrs["value"].as_tensor().unwrap().scalar_value_f32().unwrap(),
            -6.0
        );
    }

    #[test]
    fn pure_const_only_frontier_is_noop() {
        // Consts feeding a non-foldable op directly: nothing to fold.
        let mut b = GraphBuilder::new();
        let c = b.scalar(1.0);
        let p = b.constant(Tensor::scalar_bool(true));
        let _sw = b.switch(c, p).unwrap();
        let before = b.graph.len();
        let (g, stats) = constant_folding(&b.graph).unwrap();
        assert_eq!(stats.endpoints_folded, 0);
        assert_eq!(g.len(), before);
    }

    #[test]
    fn does_not_fold_across_switch() {
        // Ops downstream of Switch are not const-rooted even when every
        // other input is Const — dead branches must stay unevaluated.
        let mut b = GraphBuilder::new();
        let c = b.scalar(5.0);
        let p = b.constant(Tensor::scalar_bool(false));
        let (f_side, t_side) = b.switch(c, p).unwrap();
        let ten = b.scalar(10.0);
        let t_out = b.mul(t_side, ten);
        let one = b.scalar(1.0);
        let f_out = b.add(f_side, one);
        let _ = b.merge(vec![f_out, t_out]).unwrap();
        let (g, stats) = constant_folding(&b.graph).unwrap();
        assert_eq!(stats.endpoints_folded, 0);
        assert!(g.nodes.iter().any(|n| n.op == "Mul"), "dead branch rewritten");
    }

    #[test]
    fn stateful_and_random_ops_block_folding() {
        let mut b = GraphBuilder::new();
        let v = b.variable("v", Tensor::scalar_f32(1.0)).unwrap();
        let two = b.scalar(2.0);
        let _ = b.mul(v, two); // Variable input: not foldable
        let r = b
            .op1(
                "RandomUniform",
                "r",
                vec![],
                vec![("shape", AttrValue::Shape(crate::tensor::Shape(vec![2])))],
            )
            .unwrap();
        let _ = b.neg(r); // RandomUniform is stateful: not foldable
        let (_, stats) = constant_folding(&b.graph).unwrap();
        assert_eq!(stats.endpoints_folded, 0);
    }

    #[test]
    fn control_edges_block_folding() {
        let mut b = GraphBuilder::new();
        let a = b.scalar(1.0);
        let c = b.scalar(2.0);
        let s = b.add(a, c);
        let trigger = b.no_op("trigger");
        b.add_control_input(s.node, trigger);
        let x = b.placeholder("x", DType::F32).unwrap();
        let _ = b.mul(x, s);
        let (_, stats) = constant_folding(&b.graph).unwrap();
        assert_eq!(stats.endpoints_folded, 0, "node with control input folded");
    }

    #[test]
    fn multi_output_foldable_ports() {
        // Split a const into two ports; a placeholder consumer reads both.
        let mut b = GraphBuilder::new();
        let c = b.constant(Tensor::from_f32(vec![4], vec![1., 2., 3., 4.]).unwrap());
        let parts = b.split(c, 0, 2).unwrap();
        let x = b.placeholder("x", DType::F32).unwrap();
        let s = b.add(parts[0], x);
        let _ = b.add(parts[1], s);
        let (g, stats) = constant_folding(&b.graph).unwrap();
        assert_eq!(stats.endpoints_folded, 2);
        assert!(g.nodes.iter().all(|n| n.op != "Split"));
        let consts: Vec<&Node> =
            g.nodes.iter().filter(|n| n.name.contains("folded")).collect();
        assert_eq!(consts.len(), 2);
    }

    #[test]
    fn zero_consumer_pure_target_survives() {
        // A pure node run purely as a target must not be deleted.
        let mut b = GraphBuilder::new();
        let c = b.scalar(3.0);
        let _target = b.neg(c);
        let (g, _) = constant_folding(&b.graph).unwrap();
        assert!(g.nodes.iter().any(|n| n.op == "Neg"));
    }
}
