//! §5 elementwise-chain fusion: collapse linear chains of unary/binary
//! elementwise ops into a single `FusedElementwise` node whose kernel
//! (`kernels::fused`) interprets the recorded op sequence in one pass over
//! the data — N kernel launches and N−1 intermediate tensors become one
//! launch and zero intermediates.
//!
//! A *chain* is a maximal run `n₁ → n₂ → … → nₖ` (k ≥ 2) where every `nᵢ`
//! is a fusable elementwise op, each interior link is the producer's *only*
//! data edge, and no member carries control edges. Binary members
//! contribute their second ("extra") operand as an additional fused-node
//! input; an extra produced inside the chain would make the region a DAG
//! rather than a chain, so it ends the chain instead. Members must agree
//! on `requested_device` — fusing across a device constraint would move
//! work the user pinned.
//!
//! Fusion never crosses control flow: `Switch`/`Merge`/`Enter`/… are not
//! fusable, and a fused node inside a loop body behaves exactly like the
//! chain it replaced (dead tokens propagate through it unchanged).

use crate::error::Result;
use crate::graph::{Endpoint, Graph, Node, NodeId};
use crate::kernels::fused::{steps_to_attr, Step};
use std::collections::{BTreeMap, HashMap, HashSet};

/// Statistics from one fusion run.
#[derive(Debug, Default, Clone, PartialEq)]
pub struct FuseStats {
    pub nodes_before: usize,
    /// Chains replaced by a FusedElementwise node.
    pub chains_fused: usize,
    /// Total elementwise nodes absorbed into fused nodes.
    pub nodes_fused: usize,
    /// Net nodes removed (absorbed minus fused nodes added).
    pub nodes_removed: usize,
}

const UNARY: &[&str] = &[
    "Neg", "Exp", "Log", "Sqrt", "Rsqrt", "Abs", "Sign", "Square", "Tanh", "Reciprocal", "ReLU",
    "Sigmoid",
];
const BINARY: &[&str] = &["Add", "Sub", "Mul", "Div", "Maximum", "Minimum", "Pow"];

/// Run elementwise-chain fusion over `graph`. Pure graph→graph.
pub fn fuse_elementwise_chains(graph: &Graph) -> Result<(Graph, FuseStats)> {
    let mut stats = FuseStats { nodes_before: graph.len(), ..Default::default() };
    let order = graph.topo_order()?;
    let fanout = graph.fanout();

    let fusable = |id: NodeId| -> bool {
        let n = graph.node(id);
        (UNARY.contains(&n.op.as_str()) || BINARY.contains(&n.op.as_str()))
            && n.control_inputs.is_empty()
            && fanout.control[id.0].is_empty()
    };

    // ---- chain discovery -------------------------------------------------
    // Topological sweep with greedy forward extension: by the time a node
    // is visited unclaimed, no predecessor could have absorbed it, so it is
    // a chain head.
    let mut in_chain = vec![false; graph.len()];
    let mut chains: Vec<Vec<NodeId>> = Vec::new();
    for &id in &order {
        if in_chain[id.0] || !fusable(id) {
            continue;
        }
        let mut chain = vec![id];
        let mut members: HashSet<NodeId> = HashSet::from([id]);
        loop {
            let t = *chain.last().unwrap();
            if fanout.data[t.0].len() != 1 {
                break; // fan-out ends the chain (duplicating work is a non-goal)
            }
            let (c, slot) = fanout.data[t.0][0];
            if in_chain[c.0] || !fusable(c) {
                break;
            }
            let cn = graph.node(c);
            if cn.inputs[slot].port != 0 {
                break; // defensive: fusable producers are single-output
            }
            if BINARY.contains(&cn.op.as_str()) && members.contains(&cn.inputs[1 - slot].node) {
                break; // diamond back into the chain: not a linear chain
            }
            if cn.requested_device != graph.node(id).requested_device {
                break;
            }
            chain.push(c);
            members.insert(c);
        }
        if chain.len() >= 2 {
            for &m in &chain {
                in_chain[m.0] = true;
            }
            chains.push(chain);
        }
    }
    if chains.is_empty() {
        return Ok((graph.clone(), stats));
    }

    // ---- build fused nodes -----------------------------------------------
    let mut rewritten = graph.clone();
    // tail of each chain → its fused node (edges onto a tail, including
    // inputs of *other* fused nodes, are redirected through this map).
    let mut tail_map: HashMap<NodeId, NodeId> = HashMap::new();
    for chain in &chains {
        let head = chain[0];
        let hn = graph.node(head);
        let mut inputs: Vec<Endpoint> = vec![hn.inputs[0]];
        let mut steps: Vec<Step> = Vec::with_capacity(chain.len());
        if BINARY.contains(&hn.op.as_str()) {
            inputs.push(hn.inputs[1]);
            steps.push(Step { op: hn.op.clone(), acc_left: true, arg: Some(1) });
        } else {
            steps.push(Step { op: hn.op.clone(), acc_left: true, arg: None });
        }
        let mut prev = head;
        for &m in &chain[1..] {
            let mn = graph.node(m);
            if BINARY.contains(&mn.op.as_str()) {
                let slot = mn
                    .inputs
                    .iter()
                    .position(|e| e.node == prev)
                    .expect("chain member consumes its predecessor");
                let arg = inputs.len();
                inputs.push(mn.inputs[1 - slot]);
                steps.push(Step { op: mn.op.clone(), acc_left: slot == 0, arg: Some(arg) });
            } else {
                steps.push(Step { op: mn.op.clone(), acc_left: true, arg: None });
            }
            prev = m;
        }
        let tail = *chain.last().unwrap();
        let name = rewritten.unique_name(&format!("fused/{}", graph.node(head).name));
        let mut attrs = BTreeMap::new();
        attrs.insert("ops".to_string(), steps_to_attr(&steps));
        // Propagate `T` only when the tail declared one: a guessed default
        // would mislead dtype inference (the kernel itself dispatches on
        // the runtime dtype and never reads it).
        if let Some(t) = graph.node(tail).attr_opt("T") {
            attrs.insert("T".to_string(), t.clone());
        }
        let fid = rewritten.add(Node {
            name,
            op: "FusedElementwise".into(),
            inputs,
            control_inputs: vec![],
            attrs,
            requested_device: hn.requested_device.clone(),
            assigned_device: None,
        })?;
        tail_map.insert(tail, fid);
        stats.chains_fused += 1;
        stats.nodes_fused += chain.len();
    }

    // ---- redirect every edge off the chain tails -------------------------
    for id in rewritten.ids().collect::<Vec<_>>() {
        let new_inputs: Vec<Endpoint> = rewritten
            .node(id)
            .inputs
            .iter()
            .map(|&e| match tail_map.get(&e.node) {
                Some(&f) if e.port == 0 => Endpoint::new(f, 0),
                _ => e,
            })
            .collect();
        rewritten.node_mut(id).inputs = new_inputs;
    }

    // ---- prune the absorbed members --------------------------------------
    let member_set: Vec<bool> = in_chain;
    let mut roots: Vec<NodeId> = graph.ids().filter(|id| !member_set[id.0]).collect();
    roots.extend((graph.len()..rewritten.len()).map(NodeId));
    let keep = rewritten.reachable_from(&roots);
    stats.nodes_removed = rewritten.len() - keep.len();
    let (out, _) = rewritten.subgraph(&keep);
    Ok((out, stats))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ops::builder::GraphBuilder;
    use crate::tensor::DType;

    fn fused_nodes(g: &Graph) -> Vec<&Node> {
        g.nodes.iter().filter(|n| n.op == "FusedElementwise").collect()
    }

    #[test]
    fn linear_chain_fuses_to_one_node() {
        let mut b = GraphBuilder::new();
        let x = b.placeholder("x", DType::F32).unwrap();
        let half = b.scalar(0.5);
        let m = b.mul(x, half);
        let n = b.neg(m);
        let t = b.tanh(n);
        let _sink = b.op("_Fetch", "f", vec![t], vec![("name", "t:0".into())]).unwrap();
        let (g, stats) = fuse_elementwise_chains(&b.graph).unwrap();
        assert_eq!(stats.chains_fused, 1);
        assert_eq!(stats.nodes_fused, 3);
        let f = fused_nodes(&g);
        assert_eq!(f.len(), 1);
        // inputs: primary (x) + the scalar extra.
        assert_eq!(f[0].inputs.len(), 2);
        assert_eq!(
            f[0].attrs["ops"].as_list_str().unwrap(),
            &["Mul,r,1".to_string(), "Neg".into(), "Tanh".into()]
        );
        assert!(g.nodes.iter().all(|n| !matches!(n.op.as_str(), "Mul" | "Neg" | "Tanh")));
    }

    #[test]
    fn binary_side_recorded() {
        // y = c - tanh(x): predecessor feeds Sub's input 1 → acc on right.
        let mut b = GraphBuilder::new();
        let x = b.placeholder("x", DType::F32).unwrap();
        let c = b.scalar(3.0);
        let t = b.tanh(x);
        let s = b.sub(c, t);
        let _sink = b.op("_Fetch", "f", vec![s], vec![("name", "s:0".into())]).unwrap();
        let (g, stats) = fuse_elementwise_chains(&b.graph).unwrap();
        assert_eq!(stats.chains_fused, 1);
        let f = fused_nodes(&g);
        assert_eq!(
            f[0].attrs["ops"].as_list_str().unwrap(),
            &["Tanh".to_string(), "Sub,l,1".into()]
        );
    }

    #[test]
    fn fanout_breaks_chain() {
        let mut b = GraphBuilder::new();
        let x = b.placeholder("x", DType::F32).unwrap();
        let n = b.neg(x);
        let t = b.tanh(n);
        let u = b.exp(n); // second consumer of n
        let _s = b.add(t, u);
        let (g, stats) = fuse_elementwise_chains(&b.graph).unwrap();
        // n cannot fuse forward (fan-out 2); t and u are single-node chains.
        // The only chain is... none of length >= 2 except possibly via Add:
        // t -> Add has two chain-external producers, Add consumes t and u.
        // tanh(n) -> Add: t's single consumer is Add, which is fusable, so
        // [t, Add] fuses (u stays an extra).
        assert!(stats.chains_fused >= 1);
        assert!(g.nodes.iter().any(|n| n.op == "Neg"), "shared producer absorbed");
    }

    #[test]
    fn diamond_does_not_fuse_into_cycle() {
        // y = x + neg(x): Add's other operand comes from inside would-be
        // chain [neg] — chain stops, graph stays acyclic.
        let mut b = GraphBuilder::new();
        let x = b.placeholder("x", DType::F32).unwrap();
        let n = b.neg(x);
        let y = b.add(n, n);
        let _sink = b.tanh(y);
        let (g, _) = fuse_elementwise_chains(&b.graph).unwrap();
        assert!(g.topo_order().is_ok());
    }

    #[test]
    fn control_edges_block_fusion() {
        let mut b = GraphBuilder::new();
        let x = b.placeholder("x", DType::F32).unwrap();
        let n = b.neg(x);
        let t = b.tanh(n);
        let trigger = b.no_op("trigger");
        b.add_control_input(n.node, trigger);
        let _sink = b.exp(t);
        let (g, _) = fuse_elementwise_chains(&b.graph).unwrap();
        assert!(g.nodes.iter().any(|n| n.op == "Neg"), "controlled node fused away");
    }

    #[test]
    fn device_constraint_breaks_chain() {
        let mut b = GraphBuilder::new();
        let x = b.placeholder("x", DType::F32).unwrap();
        let n = b.with_device("/device:cpu:0", |b| b.neg(x));
        let t = b.with_device("/device:cpu:1", |b| b.tanh(n));
        let _sink = b.op("_Fetch", "f", vec![t], vec![("name", "t:0".into())]).unwrap();
        let (_, stats) = fuse_elementwise_chains(&b.graph).unwrap();
        assert_eq!(stats.chains_fused, 0);
    }

    #[test]
    fn adjacent_chains_share_an_edge_correctly() {
        // A tail with multiple consumers feeds a second chain; the second
        // fused node must read the first fused node, not the dead tail.
        let mut b = GraphBuilder::new();
        let x = b.placeholder("x", DType::F32).unwrap();
        let n = b.neg(x);
        let t = b.tanh(n); // chain 1: [Neg, Tanh]; tail t has 2 consumers
        let e1 = b.exp(t);
        let s1 = b.sqrt(e1); // chain 2: [Exp, Sqrt]
        let a2 = b.op1("Abs", "Abs", vec![t], vec![]).unwrap();
        let q2 = b.square(a2); // chain 3: [Abs, Square]
        let _sink = b.add(s1, q2);
        let (g, stats) = fuse_elementwise_chains(&b.graph).unwrap();
        assert!(stats.chains_fused >= 2);
        assert!(g.topo_order().is_ok());
        // No fused node may reference a removed member: every input of
        // every node must exist in the new graph (subgraph guarantees it),
        // and no plain Tanh remains.
        assert!(g.nodes.iter().all(|n| n.op != "Tanh"));
    }

    #[test]
    fn single_ops_left_alone() {
        let mut b = GraphBuilder::new();
        let x = b.placeholder("x", DType::F32).unwrap();
        let n = b.neg(x);
        let _sink = b.op("_Fetch", "f", vec![n], vec![("name", "n:0".into())]).unwrap();
        let (g, stats) = fuse_elementwise_chains(&b.graph).unwrap();
        assert_eq!(stats.chains_fused, 0);
        assert!(g.nodes.iter().any(|n| n.op == "Neg"));
    }
}
