//! Graph optimization passes (§5).

pub mod cse;
pub mod schedule;

pub use cse::common_subexpression_elimination;
pub use schedule::{schedule_recvs, schedule_recvs_global};
