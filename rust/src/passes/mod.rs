//! Graph optimization passes (§5 "Optimizations").
//!
//! Passes run inside `Session::build_step`, after pruning and before
//! placement/partitioning, so they see exactly the subgraph a Run will
//! execute and their cost is paid once per cached signature:
//!
//! * [`cse`] — §5.1 common subexpression elimination over the pruned
//!   graph (Click's GVN-style hashing of op, inputs, and attrs).
//! * [`schedule`] — §5.2 Recv scheduling: delay the start of Recv ops
//!   until just before their consumers need them, bounding peak memory
//!   on the receiving device instead of pulling every tensor eagerly.
//!
//! Each pass is pure graph→graph (plus stats), so they compose and are
//! individually ablatable — `SessionOptions::enable_cse` /
//! `enable_recv_scheduling` gate them, and the ablation benches flip
//! those flags to measure each pass's contribution.

pub mod cse;
pub mod schedule;

pub use cse::common_subexpression_elimination;
pub use schedule::{schedule_recvs, schedule_recvs_global};
