//! Graph optimization passes (§5 "Optimizations") and the [`PassManager`]
//! that pipelines them.
//!
//! Build-time passes are pure graph→graph rewrites (plus stats) that run
//! inside `Session::build_step` between pruning and placement, so they see
//! exactly the subgraph a Run will execute and their cost is paid once per
//! cached step signature:
//!
//! * [`constant_fold`] — evaluate maximal Const-rooted subgraphs at build
//!   time with the single-device executor and replace them with Const
//!   nodes.
//! * [`simplify`] — arithmetic identities: `x*1`, `x+0`, `x-0`, `x/1`,
//!   `x^1`, double `Neg`/`Transpose`, Identity-chain collapse.
//! * [`cse`] — §5.1 common subexpression elimination (Click's GVN-style
//!   hashing of op, inputs, and attrs).
//! * [`fuse`] — collapse linear elementwise chains into single
//!   `FusedElementwise` nodes executed in one pass over the data.
//!
//! The standard order is **fold → simplify → cse → fuse**: folding first
//! materializes const subtrees (including identities buried inside them),
//! simplification then strips identities around non-const values and
//! shortens chains, CSE dedups what is left (including freshly folded
//! equal constants), and fusion runs last because a `FusedElementwise`
//! node would otherwise hide its members from the pattern-matching passes.
//! Every pass preserves three invariants: rewrites never cross control
//! flow (`Switch`/`Merge`/frames), never touch stateful ops, and never
//! drop a node that carries control edges.
//!
//! [`schedule`] — §5.2 Recv scheduling — is *not* a PassManager pass: it
//! runs after placement/partitioning (it needs the Send/Recv pairing), and
//! stays gated by `SessionOptions::enable_recv_scheduling`.
//!
//! Each pipeline pass is individually gated by `SessionOptions`
//! (`enable_constant_folding`, `enable_arithmetic_simplification`,
//! `enable_cse`, `enable_elementwise_fusion`); `benches/optimizer.rs`
//! flips those flags to measure each pass's contribution, and
//! `tests/optimizer.rs` proves every flag combination semantics-preserving.

pub mod constant_fold;
pub mod cse;
pub mod fuse;
pub mod schedule;
pub mod simplify;

pub use constant_fold::{constant_folding, FoldStats};
pub use cse::common_subexpression_elimination;
pub use fuse::{fuse_elementwise_chains, FuseStats};
pub use schedule::{schedule_recvs, schedule_recvs_global};
pub use simplify::{arithmetic_simplification, SimplifyStats};

use crate::error::Result;
use crate::graph::Graph;

/// Uniform record of one pass execution inside a [`PassManager`] run.
#[derive(Debug, Clone)]
pub struct PassReport {
    pub name: String,
    pub nodes_before: usize,
    pub nodes_after: usize,
    /// Pass-specific rewrite count: folded endpoints, simplified nodes,
    /// CSE-removed duplicates, fused chains.
    pub rewrites: usize,
}

/// Per-pass reports for one pipeline run (one per cached step signature).
#[derive(Debug, Clone, Default)]
pub struct PipelineStats {
    pub passes: Vec<PassReport>,
}

impl PipelineStats {
    /// The report for a pass by name, if it ran.
    pub fn report(&self, name: &str) -> Option<&PassReport> {
        self.passes.iter().find(|p| p.name == name)
    }

    pub fn total_rewrites(&self) -> usize {
        self.passes.iter().map(|p| p.rewrites).sum()
    }
}

/// A pure graph→graph rewrite returning the new graph and a rewrite count.
pub type PassFn = Box<dyn Fn(&Graph) -> Result<(Graph, usize)> + Send + Sync>;

/// Runs an ordered list of pure passes over a graph, collecting per-pass
/// stats. Registration order is execution order; because every pass is
/// graph→graph, any subset composes (the ablation property the benches
/// and equivalence tests rely on).
#[derive(Default)]
pub struct PassManager {
    passes: Vec<(String, PassFn)>,
}

impl PassManager {
    pub fn new() -> PassManager {
        PassManager::default()
    }

    /// Append a pass under `name` (builder style).
    pub fn register(mut self, name: &str, pass: PassFn) -> PassManager {
        self.passes.push((name.to_string(), pass));
        self
    }

    pub fn len(&self) -> usize {
        self.passes.len()
    }

    pub fn is_empty(&self) -> bool {
        self.passes.is_empty()
    }

    /// The standard build-step pipeline with per-pass ablation flags, in
    /// the canonical fold → simplify → cse → fuse order (module docs
    /// explain why).
    pub fn standard(fold: bool, simplify: bool, cse: bool, fuse: bool) -> PassManager {
        let mut pm = PassManager::new();
        if fold {
            pm = pm.register(
                "constant_folding",
                Box::new(|g| {
                    let (g, s) = constant_folding(g)?;
                    Ok((g, s.endpoints_folded))
                }),
            );
        }
        if simplify {
            pm = pm.register(
                "arithmetic_simplification",
                Box::new(|g| {
                    let (g, s) = arithmetic_simplification(g)?;
                    Ok((g, s.rewrites))
                }),
            );
        }
        if cse {
            pm = pm.register(
                "cse",
                Box::new(|g| {
                    let (g, s) = common_subexpression_elimination(g)?;
                    Ok((g, s.nodes_removed))
                }),
            );
        }
        if fuse {
            pm = pm.register(
                "elementwise_fusion",
                Box::new(|g| {
                    let (g, s) = fuse_elementwise_chains(g)?;
                    Ok((g, s.chains_fused))
                }),
            );
        }
        pm
    }

    /// Run every registered pass in order.
    pub fn run(&self, graph: &Graph) -> Result<(Graph, PipelineStats)> {
        let mut current = graph.clone();
        let mut stats = PipelineStats::default();
        for (name, pass) in &self.passes {
            let nodes_before = current.len();
            let (next, rewrites) = pass(&current)?;
            stats.passes.push(PassReport {
                name: name.clone(),
                nodes_before,
                nodes_after: next.len(),
                rewrites,
            });
            current = next;
        }
        Ok((current, stats))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ops::builder::GraphBuilder;
    use crate::tensor::DType;

    #[test]
    fn empty_manager_is_identity() {
        let mut b = GraphBuilder::new();
        let x = b.scalar(1.0);
        let _ = b.neg(x);
        let (g, stats) = PassManager::new().run(&b.graph).unwrap();
        assert_eq!(g.len(), b.graph.len());
        assert!(stats.passes.is_empty());
        assert_eq!(stats.total_rewrites(), 0);
    }

    #[test]
    fn standard_pipeline_reports_every_enabled_pass() {
        let pm = PassManager::standard(true, true, true, true);
        assert_eq!(pm.len(), 4);
        let mut b = GraphBuilder::new();
        let x = b.placeholder("x", DType::F32).unwrap();
        let one = b.scalar(1.0);
        let m = b.mul(x, one); // simplification fodder
        let c1 = b.scalar(2.0);
        let c2 = b.scalar(3.0);
        let c = b.mul(c1, c2); // folding fodder
        let a = b.add(m, c);
        let t = b.tanh(a);
        let _sink = b.neg(t); // fusion fodder (Add→Tanh→Neg chain)
        let (g, stats) = pm.run(&b.graph).unwrap();
        for name in ["constant_folding", "arithmetic_simplification", "cse", "elementwise_fusion"]
        {
            assert!(stats.report(name).is_some(), "missing report for {name}");
        }
        assert!(stats.report("constant_folding").unwrap().rewrites >= 1);
        assert!(stats.report("arithmetic_simplification").unwrap().rewrites >= 1);
        assert!(stats.report("elementwise_fusion").unwrap().rewrites >= 1);
        assert!(g.len() < b.graph.len());
        assert!(g.nodes.iter().any(|n| n.op == "FusedElementwise"));
    }

    #[test]
    fn subsets_compose() {
        // Any single pass runs standalone on the same graph.
        let build = || {
            let mut b = GraphBuilder::new();
            let x = b.placeholder("x", DType::F32).unwrap();
            let one = b.scalar(1.0);
            let m = b.mul(x, one);
            let t = b.tanh(m);
            let _ = b.neg(t);
            b
        };
        for (fold, simplify, cse, fuse) in
            [(true, false, false, false), (false, true, false, false), (false, false, true, false), (false, false, false, true)]
        {
            let b = build();
            let pm = PassManager::standard(fold, simplify, cse, fuse);
            assert_eq!(pm.len(), 1);
            pm.run(&b.graph).unwrap();
        }
    }
}
