//! §5.2 Receive-node scheduling: "if no precautions are taken, [Receive]
//! nodes may start much earlier than necessary, possibly all at once when
//! execution starts. By performing an as-soon-as-possible/as-late-as-
//! possible (ASAP/ALAP) calculation … we analyze the critical paths of
//! graphs, in order to estimate when to start the Receive nodes. We then
//! insert control edges with the aim of delaying the start of these nodes
//! until just before their results are needed."
//!
//! Implementation: compute ASAP and ALAP times over the (partitioned,
//! per-device) graph using the cost model; for each Recv with slack
//! (ALAP − ASAP > 0), pick a *gate*: a node whose completion time is
//! closest to (but not after) the Recv's ALAP start, and add a control
//! edge gate → Recv. Delaying the Recv shortens the window its tensor is
//! resident, cutting peak memory (experiment E12 measures this).

use crate::error::Result;
use crate::graph::{Graph, NodeId};
use crate::placement::CostModel;
use std::collections::HashMap;

#[derive(Debug, Default, Clone)]
pub struct ScheduleStats {
    pub recvs_considered: usize,
    pub control_edges_added: usize,
}

/// Schedule every partition's Recvs *globally*: gating decisions must see
/// the cross-partition Send→Recv pairing, or a gate can wait (through the
/// other device) on a tensor sent after the gated Recv — distributed
/// deadlock. Builds the combined dependency graph, then gates each Recv on
/// a same-partition node that is provably not downstream globally.
pub fn schedule_recvs_global(
    parts: &mut [crate::partition::Partition],
    cost: &CostModel,
) -> Result<ScheduleStats> {
    // ---- combined graph: nodes of all partitions + send→recv edges ------
    let offsets: Vec<usize> = parts
        .iter()
        .scan(0usize, |acc, p| {
            let o = *acc;
            *acc += p.graph.len();
            Some(o)
        })
        .collect();
    let total: usize = parts.iter().map(|p| p.graph.len()).sum();
    // preds/succs in combined index space.
    let mut succs: Vec<Vec<usize>> = vec![Vec::new(); total];
    let mut key_send: HashMap<String, usize> = HashMap::new();
    let mut key_recv: HashMap<String, usize> = HashMap::new();
    for (pi, p) in parts.iter().enumerate() {
        let off = offsets[pi];
        for id in p.graph.ids() {
            let n = p.graph.node(id);
            for e in &n.inputs {
                if p.graph.node(e.node).op != "NextIteration" {
                    succs[off + e.node.0].push(off + id.0);
                }
            }
            for c in &n.control_inputs {
                if p.graph.node(*c).op != "NextIteration" {
                    succs[off + c.0].push(off + id.0);
                }
            }
            if n.op == "_Send" {
                key_send.insert(n.attr("key")?.as_str()?.to_string(), off + id.0);
            } else if n.op == "_Recv" {
                key_recv.insert(n.attr("key")?.as_str()?.to_string(), off + id.0);
            }
        }
    }
    for (key, &s) in &key_send {
        if let Some(&r) = key_recv.get(key) {
            succs[s].push(r);
        }
    }

    let mut stats = ScheduleStats::default();
    // Per-partition ASAP finish estimates (cheap, local).
    for pi in 0..parts.len() {
        let recvs: Vec<NodeId> = parts[pi]
            .graph
            .ids()
            .filter(|&id| parts[pi].graph.node(id).op == "_Recv")
            .collect();
        if recvs.is_empty() {
            continue;
        }
        let order = parts[pi].graph.topo_order()?;
        let devices: Vec<String> = parts[pi]
            .graph
            .ids()
            .map(|id| parts[pi].graph.node(id).assigned_device.clone().unwrap_or_default())
            .collect();
        let mut asap = vec![0f64; parts[pi].graph.len()];
        for &id in &order {
            let n = parts[pi].graph.node(id);
            let ready = n
                .inputs
                .iter()
                .map(|e| e.node)
                .chain(n.control_inputs.iter().copied())
                .map(|p| asap[p.0])
                .fold(0f64, f64::max);
            asap[id.0] = ready + cost.node_cost_us(n, &devices[id.0]);
        }
        let makespan = asap.iter().cloned().fold(0f64, f64::max);
        let fanout = parts[pi].graph.fanout();
        let mut alap = vec![makespan; parts[pi].graph.len()];
        for &id in order.iter().rev() {
            let ss: Vec<NodeId> = fanout.data[id.0]
                .iter()
                .map(|&(c, _)| c)
                .chain(fanout.control[id.0].iter().copied())
                .collect();
            if !ss.is_empty() {
                alap[id.0] = ss
                    .iter()
                    .map(|s| alap[s.0] - cost.node_cost_us(parts[pi].graph.node(*s), &devices[s.0]))
                    .fold(f64::INFINITY, f64::min);
            }
        }
        for recv in recvs {
            stats.recvs_considered += 1;
            let rcost = cost.node_cost_us(parts[pi].graph.node(recv), &devices[recv.0]);
            let slack = (alap[recv.0] - rcost) - (asap[recv.0] - rcost);
            if slack <= 1.0 {
                continue;
            }
            // Global downstream set of this recv over the combined graph
            // (including edges added in previous iterations).
            let gidx = offsets[pi] + recv.0;
            let mut downstream = std::collections::HashSet::new();
            let mut stack = vec![gidx];
            while let Some(cur) = stack.pop() {
                if !downstream.insert(cur) {
                    continue;
                }
                for &s in &succs[cur] {
                    stack.push(s);
                }
            }
            // Best same-partition gate: latest ASAP finish ≤ recv's ALAP
            // start, not globally downstream of the recv.
            let alap_start = alap[recv.0] - rcost;
            let mut best: Option<(f64, NodeId)> = None;
            for id in parts[pi].graph.ids() {
                if id == recv
                    || parts[pi].graph.node(id).op == "_Recv"
                    || downstream.contains(&(offsets[pi] + id.0))
                {
                    continue;
                }
                let f = asap[id.0];
                if f <= alap_start && f > asap[recv.0] - rcost {
                    match best {
                        Some((bf, _)) if bf >= f => {}
                        _ => best = Some((f, id)),
                    }
                }
            }
            if let Some((_, gate)) = best {
                let node = parts[pi].graph.node_mut(recv);
                if !node.control_inputs.contains(&gate) {
                    node.control_inputs.push(gate);
                    succs[offsets[pi] + gate.0].push(gidx);
                    stats.control_edges_added += 1;
                }
            }
        }
    }
    Ok(stats)
}

/// Add delaying control edges to `_Recv` nodes in a per-device partition
/// (single-partition variant; safe only when no other partition exists —
/// the session/master call [`schedule_recvs_global`]).
pub fn schedule_recvs(graph: &mut Graph, cost: &CostModel) -> Result<ScheduleStats> {
    let order = graph.topo_order()?;
    let n = graph.len();
    let devices: Vec<String> = graph
        .ids()
        .map(|id| graph.node(id).assigned_device.clone().unwrap_or_default())
        .collect();
    let device = |id: NodeId| -> &str { &devices[id.0] };

    // ASAP: earliest start respecting deps.
    let mut asap_finish = vec![0f64; n];
    for &id in &order {
        let node = graph.node(id);
        let ready = node
            .inputs
            .iter()
            .map(|e| e.node)
            .chain(node.control_inputs.iter().copied())
            .map(|p| asap_finish[p.0])
            .fold(0f64, f64::max);
        asap_finish[id.0] = ready + cost.node_cost_us(node, &device(id));
    }
    let makespan = asap_finish.iter().cloned().fold(0f64, f64::max);

    // ALAP: latest finish that doesn't stretch the makespan.
    let fanout = graph.fanout();
    let mut alap_finish = vec![makespan; n];
    for &id in order.iter().rev() {
        let node = graph.node(id);
        let succs: Vec<NodeId> = fanout.data[id.0]
            .iter()
            .map(|&(c, _)| c)
            .chain(fanout.control[id.0].iter().copied())
            .collect();
        if !succs.is_empty() {
            let latest = succs
                .iter()
                .map(|s| alap_finish[s.0] - cost.node_cost_us(graph.node(*s), &device(*s)))
                .fold(f64::INFINITY, f64::min);
            alap_finish[id.0] = latest;
        }
        let _ = node;
    }

    let mut stats = ScheduleStats::default();
    // For each Recv with slack, gate it on the latest-finishing node whose
    // ASAP finish ≤ the Recv's ALAP start (avoiding cycles: gate must not
    // be downstream of the Recv).
    let recvs: Vec<NodeId> =
        graph.ids().filter(|&id| graph.node(id).op == "_Recv").collect();
    for recv in recvs {
        stats.recvs_considered += 1;
        let recv_cost = cost.node_cost_us(graph.node(recv), &device(recv));
        let alap_start = alap_finish[recv.0] - recv_cost;
        let slack = alap_start - (asap_finish[recv.0] - recv_cost);
        if slack <= 1.0 {
            continue; // on the critical path; leave it alone
        }
        // Downstream set of recv, recomputed against the CURRENT graph —
        // control edges added for earlier recvs create new paths, and a
        // stale fanout here can produce gating cycles.
        let cur_fanout = graph.fanout();
        let mut downstream = std::collections::HashSet::new();
        let mut stack = vec![recv];
        while let Some(cur) = stack.pop() {
            if !downstream.insert(cur) {
                continue;
            }
            for &(c, _) in &cur_fanout.data[cur.0] {
                stack.push(c);
            }
            for &c in &cur_fanout.control[cur.0] {
                stack.push(c);
            }
        }
        let mut best: Option<(f64, NodeId)> = None;
        for id in graph.ids() {
            if downstream.contains(&id) || id == recv || graph.node(id).op == "_Recv" {
                continue;
            }
            let f = asap_finish[id.0];
            if f <= alap_start {
                match best {
                    Some((bf, _)) if bf >= f => {}
                    _ => best = Some((f, id)),
                }
            }
        }
        if let Some((gate_finish, gate)) = best {
            // Only useful if the gate actually delays the recv.
            if gate_finish > asap_finish[recv.0] - recv_cost {
                let node = graph.node_mut(recv);
                if !node.control_inputs.contains(&gate) {
                    node.control_inputs.push(gate);
                    stats.control_edges_added += 1;
                }
            }
        }
    }
    Ok(stats)
}

/// A topological order biased to shrink tensor lifetimes, for the step
/// memory planner's liveness intervals (`crate::memory::liveness`): among
/// ready nodes, greedily prefer the one that *frees* the most inputs
/// (drains its producers' last remaining consumer) net of producing new
/// values — memory-aware list scheduling. Plain Kahn BFS interleaves
/// independent branches, which keeps every branch's intermediates live at
/// once; this order finishes consumers promptly so intervals — and with
/// them the planner's arena — stay tight. `NextIteration` back-edges are
/// skipped exactly as in `Graph::topo_order`.
pub fn lifetime_shrinking_order(graph: &Graph) -> Result<Vec<NodeId>> {
    let n = graph.len();
    // preds/succs with back-edges skipped, plus per-node remaining
    // consumer counts (data + control reads).
    let mut indegree = vec![0usize; n];
    let mut reads: Vec<Vec<(usize, usize)>> = vec![Vec::new(); n]; // node -> [(producer, count)]
    let mut remaining = vec![0usize; n]; // producer -> outstanding reads
    for (i, node) in graph.nodes.iter().enumerate() {
        let mut counts: HashMap<usize, usize> = HashMap::new();
        for e in &node.inputs {
            *counts.entry(e.node.0).or_insert(0) += 1;
        }
        for c in &node.control_inputs {
            *counts.entry(c.0).or_insert(0) += 1;
        }
        for (&p, &k) in &counts {
            remaining[p] += k;
            if graph.nodes[p].op != "NextIteration" {
                indegree[i] += 1;
            }
        }
        reads[i] = counts.into_iter().collect();
        reads[i].sort_unstable();
    }
    let mut succs: Vec<Vec<usize>> = vec![Vec::new(); n];
    for (i, rs) in reads.iter().enumerate() {
        for &(p, _) in rs {
            if graph.nodes[p].op != "NextIteration" {
                succs[p].push(i);
            }
        }
    }

    // Greedy selection rescans the ready set per pop — O(ready²) overall.
    // Fine for the partition sizes planning targets; on pathologically
    // wide graphs (thousands of simultaneously-ready nodes) cap the scan
    // window so build time stays near plain Kahn. Intervals from the
    // capped order are merely looser, which the arena absorbs as misses.
    const MAX_SCAN: usize = 256;
    let mut ready: Vec<usize> = (0..n).filter(|&i| indegree[i] == 0).collect();
    let mut order = Vec::with_capacity(n);
    while !ready.is_empty() {
        // Score = inputs this node would fully free − 1 if it produces
        // outputs of its own; stable (lowest index) on ties.
        let mut best = 0usize;
        let mut best_score = i64::MIN;
        for (k, &cand) in ready.iter().enumerate().take(MAX_SCAN) {
            let freed = reads[cand]
                .iter()
                .filter(|&&(p, uses)| remaining[p] == uses)
                .count() as i64;
            let allocs = if graph.nodes[cand].inputs.is_empty() || !succs[cand].is_empty() {
                1
            } else {
                0
            };
            let score = freed - allocs;
            if score > best_score || (score == best_score && cand < ready[best]) {
                best = k;
                best_score = score;
            }
        }
        let next = ready.swap_remove(best);
        order.push(NodeId(next));
        for &(p, uses) in &reads[next] {
            remaining[p] -= uses;
        }
        for &s in &succs[next] {
            indegree[s] -= 1;
            if indegree[s] == 0 {
                ready.push(s);
            }
        }
    }
    if order.len() != n {
        return Err(crate::error::Status::invalid_argument(
            "graph contains a cycle not mediated by NextIteration",
        ));
    }
    Ok(order)
}

/// Estimate peak resident tensor bytes of a partition under a serial
/// schedule — the measurable that §5.2 optimizes. Used by E12 to compare
/// ASAP (no pass) vs scheduled graphs.
pub fn estimate_peak_memory(graph: &Graph, cost: &CostModel) -> Result<f64> {
    let order = graph.topo_order()?;
    let fanout = graph.fanout();
    // Last consumer position of each node's outputs.
    let pos: HashMap<NodeId, usize> = order.iter().enumerate().map(|(i, &n)| (n, i)).collect();
    let mut last_use: Vec<usize> = graph
        .ids()
        .map(|id| {
            fanout.data[id.0]
                .iter()
                .map(|&(c, _)| pos[&c])
                .max()
                .unwrap_or(pos[&id])
        })
        .collect();
    // _Recv values materialize at their schedule position; with added
    // control edges the topo order naturally places them later.
    let mut live = 0f64;
    let mut peak = 0f64;
    let mut expiring: HashMap<usize, Vec<NodeId>> = HashMap::new();
    for (i, &id) in order.iter().enumerate() {
        live += cost.output_bytes(graph.node(id));
        peak = peak.max(live);
        let lu = last_use[id.0].max(i);
        expiring.entry(lu).or_default().push(id);
        if let Some(done) = expiring.remove(&i) {
            for d in done {
                live -= cost.output_bytes(graph.node(d));
            }
        }
        last_use[id.0] = lu;
    }
    Ok(peak)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::{AttrValue, Node};
    use std::collections::BTreeMap;

    /// Build a partition-like graph: N recvs feeding a serial chain, so
    /// early recvs have large slack.
    fn recv_chain(n: usize) -> Graph {
        let mut g = Graph::new();
        let mut prev: Option<NodeId> = None;
        for i in 0..n {
            let recv = g
                .add(Node {
                    name: format!("_recv/x{i}"),
                    op: "_Recv".into(),
                    inputs: vec![],
                    control_inputs: vec![],
                    attrs: {
                        let mut a = BTreeMap::new();
                        a.insert("key".into(), AttrValue::Str(format!("k{i}")));
                        a
                    },
                    requested_device: String::new(),
                    assigned_device: Some("/d0".into()),
                })
                .unwrap();
            let inputs = match prev {
                Some(p) => vec![crate::graph::Endpoint::new(p, 0), recv.into()],
                None => vec![recv.into(), recv.into()],
            };
            let step = g
                .add(Node {
                    name: format!("mm{i}"),
                    op: "MatMul".into(),
                    inputs,
                    control_inputs: vec![],
                    attrs: BTreeMap::new(),
                    requested_device: String::new(),
                    assigned_device: Some("/d0".into()),
                })
                .unwrap();
            prev = Some(step);
        }
        g
    }

    #[test]
    fn late_recvs_get_gated() {
        let mut g = recv_chain(6);
        let cost = CostModel::new();
        let stats = schedule_recvs(&mut g, &cost).unwrap();
        assert_eq!(stats.recvs_considered, 6);
        assert!(
            stats.control_edges_added >= 3,
            "later recvs have slack and should be delayed: {stats:?}"
        );
        // Graph must remain acyclic.
        assert!(g.topo_order().is_ok());
    }

    #[test]
    fn scheduling_reduces_estimated_peak_memory() {
        let baseline = recv_chain(8);
        let cost = CostModel::new();
        let peak_before = estimate_peak_memory(&baseline, &cost).unwrap();
        let mut scheduled = recv_chain(8);
        schedule_recvs(&mut scheduled, &cost).unwrap();
        let peak_after = estimate_peak_memory(&scheduled, &cost).unwrap();
        assert!(
            peak_after <= peak_before,
            "peak {peak_after} should not exceed ASAP peak {peak_before}"
        );
    }

    #[test]
    fn lifetime_shrinking_order_is_topological() {
        // Two independent chains into one Add: a valid topo order that
        // (unlike BFS) finishes one chain before starting the other.
        let mut b = crate::ops::builder::GraphBuilder::new();
        let x = b.scalar(1.0);
        let y = b.scalar(2.0);
        let mut l = x;
        let mut r = y;
        for _ in 0..3 {
            l = b.neg(l);
            r = b.neg(r);
        }
        let _ = b.add(l, r);
        let order = lifetime_shrinking_order(&b.graph).unwrap();
        assert_eq!(order.len(), b.graph.len());
        let mut pos = vec![0usize; b.graph.len()];
        for (i, id) in order.iter().enumerate() {
            pos[id.0] = i;
        }
        for id in b.graph.ids() {
            for e in &b.graph.node(id).inputs {
                assert!(pos[e.node.0] < pos[id.0], "order not topological");
            }
        }
    }

    #[test]
    fn lifetime_shrinking_order_handles_loops() {
        let mut b = crate::ops::builder::GraphBuilder::new();
        let zero = b.scalar(0.0);
        b.while_loop(
            "f",
            vec![zero],
            |b, v| {
                let ten = b.scalar(10.0);
                Ok(b.less(v[0], ten))
            },
            |b, v| {
                let one = b.scalar(1.0);
                Ok(vec![b.add(v[0], one)])
            },
        )
        .unwrap();
        let order = lifetime_shrinking_order(&b.graph).unwrap();
        assert_eq!(order.len(), b.graph.len());
    }

    #[test]
    fn no_recvs_no_changes() {
        let mut b = crate::ops::builder::GraphBuilder::new();
        let x = b.scalar(1.0);
        b.neg(x);
        let stats = schedule_recvs(&mut b.graph, &CostModel::new()).unwrap();
        assert_eq!(stats.recvs_considered, 0);
        assert_eq!(stats.control_edges_added, 0);
    }
}
