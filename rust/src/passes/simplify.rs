//! §5 arithmetic simplification: rewrite identities that cost a kernel
//! launch and a tensor allocation but change nothing —
//!
//! * `Identity(x)` / `StopGradient(x)` → `x` (chains collapse transitively),
//! * `x + 0`, `0 + x`, `x - 0` → `x`,
//! * `x * 1`, `1 * x`, `x / 1`, `x ^ 1` → `x`,
//! * `Neg(Neg(x))` → `x`,
//! * `Transpose(x, identity-perm)` → `x` and
//!   `Transpose(Transpose(x, p1), p2)` → `x` when `p1 ∘ p2` is the identity
//!   (two default/empty perms both reverse, so they always cancel).
//!
//! The scalar-identity rules only fire on rank-0 `Const` operands whose
//! dtype provably matches the surviving operand's (traced backward through
//! dtype-preserving ops): a rank≥1 constant of ones could *broadcast* `x`
//! to a larger shape, and a wrong-dtype operand would have
//! failed at run time — neither is an identity. One knowing deviation from
//! IEEE 754: `x + 0` forwards `x` even though `-0.0 + 0.0` is `+0.0`, so a
//! signed-zero input keeps its sign (the same choice TF's Grappler and
//! LLVM's `nsz` make; exactness everywhere else is bit-for-bit). Nodes
//! carrying control edges (either direction) are
//! left alone — bypassing them would drop happens-before constraints.
//! Everything is resolved through a replacement map in one topological
//! sweep, so nested patterns (`Neg(Neg(Neg(Neg(x))))`, `(x*1)+0`)
//! collapse fully in a single run.

use crate::error::Result;
use crate::graph::{AttrValue, Endpoint, Graph, NodeId};
use crate::tensor::TensorData;
use std::collections::HashMap;

/// Statistics from one simplification run.
#[derive(Debug, Default, Clone, PartialEq)]
pub struct SimplifyStats {
    pub nodes_before: usize,
    /// Nodes rewritten to forward one of their inputs.
    pub rewrites: usize,
    /// Nodes dropped as dead after rewriting.
    pub nodes_removed: usize,
}

/// Follow the replacement map to a final endpoint. Replacements are only
/// ever recorded for single-output nodes, hence the port-0 guard.
fn resolve(replacement: &HashMap<NodeId, Endpoint>, mut e: Endpoint) -> Endpoint {
    while e.port == 0 {
        match replacement.get(&e.node) {
            Some(&r) => e = r,
            None => break,
        }
    }
    e
}

/// Best-effort static dtype of the value flowing on `e`: follow
/// dtype-preserving ops backward until a node that declares its output
/// dtype (a Const's value tensor or a `T` attr — Placeholder, Variable,
/// and `_Feed` carry one). `None` means unknown, which keeps the identity
/// rewrites conservative.
fn static_dtype(graph: &Graph, mut e: Endpoint) -> Option<crate::tensor::DType> {
    for _ in 0..=graph.len() {
        let n = graph.node(e.node);
        if let Some(AttrValue::Tensor(t)) = n.attrs.get("value") {
            return Some(t.dtype());
        }
        if let Some(dt) = n.attrs.get("T").and_then(|a| a.as_type().ok()) {
            return Some(dt);
        }
        if n.op == "Merge" && e.port != 0 {
            return None; // port 1 is the i32 value_index, not the data
        }
        match n.op.as_str() {
            "Add" | "Sub" | "Mul" | "Div" | "Maximum" | "Minimum" | "Pow" | "Neg" | "Exp"
            | "Log" | "Sqrt" | "Rsqrt" | "Abs" | "Sign" | "Square" | "Tanh" | "Reciprocal"
            | "Identity" | "StopGradient" | "AddN" | "ReLU" | "Sigmoid" | "Transpose"
            | "Reshape" | "Concat" | "Slice" | "Tile" | "BiasAdd" | "FusedElementwise"
            | "Switch" | "Merge" | "Enter" | "Exit" | "NextIteration" | "LoopCond"
                if !n.inputs.is_empty() =>
            {
                e = n.inputs[0];
            }
            _ => return None,
        }
    }
    None
}

/// Is `cand` a rank-0 Const carrying the value `want`, whose dtype
/// *provably* matches the value flowing on `other`? The dtype condition
/// keeps the rewrite semantics-preserving both ways: a wrong-dtype operand
/// fails in `binary_elementwise` at run time, and removing the op must not
/// silently make that graph succeed — so when `other`'s dtype cannot be
/// inferred, the rewrite does not fire.
fn scalar_identity(graph: &Graph, cand: Endpoint, other: Endpoint, want: f64) -> bool {
    if cand.port != 0 {
        return false;
    }
    let n = graph.node(cand.node);
    if n.op != "Const" {
        return false;
    }
    let Some(AttrValue::Tensor(t)) = n.attrs.get("value") else { return false };
    if t.shape().rank() != 0 {
        return false;
    }
    if static_dtype(graph, other) != Some(t.dtype()) {
        return false;
    }
    match t.data() {
        TensorData::F32(v) => v[0] == want as f32,
        TensorData::F64(v) => v[0] == want,
        TensorData::I32(v) => f64::from(v[0]) == want,
        TensorData::I64(v) => v[0] as f64 == want,
        _ => false,
    }
}

/// Is `e` a Const of a float dtype? (Guard for rewrites that are only
/// runnable — hence only identities — over floats, like `Pow`.)
fn is_float_const(graph: &Graph, e: Endpoint) -> bool {
    let n = graph.node(e.node);
    matches!(
        n.attrs.get("value"),
        Some(AttrValue::Tensor(t)) if matches!(t.data(), TensorData::F32(_) | TensorData::F64(_))
    )
}

fn perm_of(graph: &Graph, id: NodeId) -> Vec<i64> {
    graph
        .node(id)
        .attrs
        .get("perm")
        .and_then(|a| a.as_list_i64().ok())
        .map(|s| s.to_vec())
        .unwrap_or_default()
}

/// Does applying `inner` then `outer` return every dimension to its place?
/// Empty perm means "reverse", which is self-inverse at every rank.
fn perms_cancel(inner: &[i64], outer: &[i64]) -> bool {
    if inner.is_empty() && outer.is_empty() {
        return true;
    }
    if inner.is_empty() || outer.is_empty() || inner.len() != outer.len() {
        return false; // mixed empty/explicit: rank unknown at build time
    }
    outer.iter().enumerate().all(|(j, &oj)| {
        (0..inner.len() as i64).contains(&oj) && inner[oj as usize] == j as i64
    })
}

fn is_identity_perm(perm: &[i64]) -> bool {
    !perm.is_empty() && perm.iter().enumerate().all(|(i, &p)| p == i as i64)
}

/// Run arithmetic simplification over `graph`. Pure graph→graph.
pub fn arithmetic_simplification(graph: &Graph) -> Result<(Graph, SimplifyStats)> {
    let mut stats = SimplifyStats { nodes_before: graph.len(), ..Default::default() };
    let order = graph.topo_order()?;
    let fanout = graph.fanout();
    let mut replacement: HashMap<NodeId, Endpoint> = HashMap::new();

    for &id in &order {
        let n = graph.node(id);
        if !n.control_inputs.is_empty() || !fanout.control[id.0].is_empty() {
            continue;
        }
        // Never rewrite a sink: a target node run for effect anchors its
        // subtree (which may reach stateful ops), and bypassing it would
        // prune that subtree away.
        if fanout.data[id.0].is_empty() {
            continue;
        }
        let target: Option<Endpoint> = match n.op.as_str() {
            "Identity" | "StopGradient" => Some(resolve(&replacement, n.inputs[0])),
            "Neg" => {
                let src = resolve(&replacement, n.inputs[0]);
                let p = graph.node(src.node);
                if src.port == 0 && p.op == "Neg" && p.control_inputs.is_empty() {
                    Some(resolve(&replacement, p.inputs[0]))
                } else {
                    None
                }
            }
            "Transpose" => {
                let perm = perm_of(graph, id);
                let src = resolve(&replacement, n.inputs[0]);
                if is_identity_perm(&perm) {
                    Some(src)
                } else {
                    let p = graph.node(src.node);
                    if src.port == 0 && p.op == "Transpose" && p.control_inputs.is_empty() {
                        let inner = perm_of(graph, src.node);
                        if perms_cancel(&inner, &perm) {
                            Some(resolve(&replacement, p.inputs[0]))
                        } else {
                            None
                        }
                    } else {
                        None
                    }
                }
            }
            "Add" => {
                let a = resolve(&replacement, n.inputs[0]);
                let b = resolve(&replacement, n.inputs[1]);
                if scalar_identity(graph, b, a, 0.0) {
                    Some(a)
                } else if scalar_identity(graph, a, b, 0.0) {
                    Some(b)
                } else {
                    None
                }
            }
            "Sub" => {
                let a = resolve(&replacement, n.inputs[0]);
                let b = resolve(&replacement, n.inputs[1]);
                if scalar_identity(graph, b, a, 0.0) {
                    Some(a)
                } else {
                    None
                }
            }
            "Mul" => {
                let a = resolve(&replacement, n.inputs[0]);
                let b = resolve(&replacement, n.inputs[1]);
                if scalar_identity(graph, b, a, 1.0) {
                    Some(a)
                } else if scalar_identity(graph, a, b, 1.0) {
                    Some(b)
                } else {
                    None
                }
            }
            "Div" => {
                let a = resolve(&replacement, n.inputs[0]);
                let b = resolve(&replacement, n.inputs[1]);
                if scalar_identity(graph, b, a, 1.0) {
                    Some(a)
                } else {
                    None
                }
            }
            "Pow" => {
                let a = resolve(&replacement, n.inputs[0]);
                let b = resolve(&replacement, n.inputs[1]);
                // Pow kernels exist only for floats; an integer Pow errors
                // at run time and must not be legalized away.
                if scalar_identity(graph, b, a, 1.0) && is_float_const(graph, b) {
                    Some(a)
                } else {
                    None
                }
            }
            _ => None,
        };
        if let Some(t) = target {
            replacement.insert(id, t);
            stats.rewrites += 1;
        }
    }

    if replacement.is_empty() {
        return Ok((graph.clone(), stats));
    }

    let mut rewritten = graph.clone();
    for id in rewritten.ids().collect::<Vec<_>>() {
        let new_inputs: Vec<Endpoint> =
            rewritten.node(id).inputs.iter().map(|&e| resolve(&replacement, e)).collect();
        rewritten.node_mut(id).inputs = new_inputs;
    }

    // Prune from the graph's true sinks (no consumers at all) that were not
    // themselves rewritten away; bypassed nodes and their now-exclusive
    // operands (e.g. the scalar 1) fall out.
    let roots: Vec<NodeId> = graph
        .ids()
        .filter(|id| {
            fanout.data[id.0].is_empty()
                && fanout.control[id.0].is_empty()
                && !replacement.contains_key(id)
        })
        .collect();
    let keep = rewritten.reachable_from(&roots);
    stats.nodes_removed = rewritten.len() - keep.len();
    let (out, _) = rewritten.subgraph(&keep);
    Ok((out, stats))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ops::builder::GraphBuilder;
    use crate::tensor::{DType, Tensor};

    fn simplified(b: &GraphBuilder) -> (Graph, SimplifyStats) {
        arithmetic_simplification(&b.graph).unwrap()
    }

    #[test]
    fn mul_one_add_zero_collapse() {
        let mut b = GraphBuilder::new();
        let x = b.placeholder("x", DType::F32).unwrap();
        let one = b.scalar(1.0);
        let zero = b.scalar(0.0);
        let m = b.mul(x, one);
        let a = b.add(m, zero);
        let _sink = b.neg(a);
        let (g, stats) = simplified(&b);
        assert_eq!(stats.rewrites, 2);
        let neg = g.nodes.iter().find(|n| n.op == "Neg").unwrap();
        assert_eq!(g.node(neg.inputs[0].node).op, "Placeholder");
        // The bypassed ops and the dead scalars are gone.
        assert!(g.nodes.iter().all(|n| n.op != "Mul" && n.op != "Add"));
        assert_eq!(g.len(), 2);
    }

    #[test]
    fn sub_zero_div_one_pow_one() {
        let mut b = GraphBuilder::new();
        let x = b.placeholder("x", DType::F32).unwrap();
        let one = b.scalar(1.0);
        let zero = b.scalar(0.0);
        let s = b.sub(x, zero);
        let d = b.div(s, one);
        let p = b.op1("Pow", "Pow", vec![d, one], vec![]).unwrap();
        let _sink = b.neg(p);
        let (g, stats) = simplified(&b);
        assert_eq!(stats.rewrites, 3);
        let neg = g.nodes.iter().find(|n| n.op == "Neg").unwrap();
        assert_eq!(g.node(neg.inputs[0].node).op, "Placeholder");
    }

    #[test]
    fn zero_minus_x_not_rewritten() {
        let mut b = GraphBuilder::new();
        let x = b.placeholder("x", DType::F32).unwrap();
        let zero = b.scalar(0.0);
        let s = b.sub(zero, x); // 0 - x = -x, NOT x
        let _sink = b.neg(s);
        let (g, stats) = simplified(&b);
        assert_eq!(stats.rewrites, 0);
        assert!(g.nodes.iter().any(|n| n.op == "Sub"));
    }

    #[test]
    fn wrong_dtype_identity_not_rewritten() {
        // Mul(x_f32, Const 1_i32) errors at run time (dtype mismatch); the
        // pass must not silently legalize it by removing the Mul.
        let mut b = GraphBuilder::new();
        let x = b.placeholder("x", DType::F32).unwrap();
        let one_i32 = b.constant(Tensor::scalar_i32(1));
        let m = b.op1("Mul", "Mul", vec![x, one_i32], vec![]).unwrap();
        let _sink = b.neg(m);
        let (_, stats) = simplified(&b);
        assert_eq!(stats.rewrites, 0);
    }

    #[test]
    fn integer_pow_one_not_rewritten() {
        // Pow has no integer kernel; Pow(x_i32, 1_i32) errors at run time
        // and must not be legalized away.
        let mut b = GraphBuilder::new();
        let x = b.placeholder("x", DType::I32).unwrap();
        let one = b.constant(Tensor::scalar_i32(1));
        let p = b.op1("Pow", "Pow", vec![x, one], vec![]).unwrap();
        let _sink = b.op1("Abs", "Abs", vec![p], vec![]).unwrap();
        let (g, stats) = simplified(&b);
        assert_eq!(stats.rewrites, 0);
        assert!(g.nodes.iter().any(|n| n.op == "Pow"));
    }

    #[test]
    fn f64_identities_simplify_and_f32_const_against_f64_does_not() {
        // x_f64 * 1.0_f32 would error at run time → kept (the default-F32
        // trap: the const matches F32, but the operand provably is not).
        let mut b = GraphBuilder::new();
        let x = b.placeholder("x", DType::F64).unwrap();
        let one_f32 = b.scalar(1.0);
        let m = b.op1("Mul", "Mul", vec![x, one_f32], vec![]).unwrap();
        let _sink = b.neg(m);
        let (_, stats) = simplified(&b);
        assert_eq!(stats.rewrites, 0);
        // x_f64 * 1.0_f64 is a true identity → simplified, traced through
        // a dtype-preserving Tanh in between.
        let mut b2 = GraphBuilder::new();
        let x2 = b2.placeholder("x", DType::F64).unwrap();
        let t2 = b2.tanh(x2);
        let one_f64 = b2.constant(
            Tensor::new(crate::tensor::Shape::scalar(), TensorData::F64(vec![1.0])).unwrap(),
        );
        let m2 = b2.op1("Mul", "Mul", vec![t2, one_f64], vec![]).unwrap();
        let _sink2 = b2.neg(m2);
        let (_, s2) = simplified(&b2);
        assert_eq!(s2.rewrites, 1);
    }

    #[test]
    fn rank1_ones_not_an_identity() {
        // x * ones([3]) broadcasts a scalar x; must not be rewritten.
        let mut b = GraphBuilder::new();
        let x = b.placeholder("x", DType::F32).unwrap();
        let ones = b.constant(Tensor::from_f32(vec![3], vec![1., 1., 1.]).unwrap());
        let m = b.mul(x, ones);
        let _sink = b.neg(m);
        let (_, stats) = simplified(&b);
        assert_eq!(stats.rewrites, 0);
    }

    #[test]
    fn identity_chains_and_double_neg() {
        let mut b = GraphBuilder::new();
        let x = b.placeholder("x", DType::F32).unwrap();
        let i1 = b.identity(x);
        let i2 = b.identity(i1);
        let n1 = b.neg(i2);
        let n2 = b.neg(n1);
        let n3 = b.neg(n2);
        let n4 = b.neg(n3);
        let _sink = b.tanh(n4);
        let (g, stats) = simplified(&b);
        assert_eq!(stats.rewrites, 4, "2 identities + 2 double-neg pairs");
        let tanh = g.nodes.iter().find(|n| n.op == "Tanh").unwrap();
        assert_eq!(g.node(tanh.inputs[0].node).op, "Placeholder");
        assert_eq!(g.len(), 2);
    }

    #[test]
    fn transpose_pairs_cancel() {
        let mut b = GraphBuilder::new();
        let x = b.placeholder("x", DType::F32).unwrap();
        let t1 = b.transpose(x, vec![1, 0]);
        let t2 = b.transpose(t1, vec![1, 0]);
        let _sink = b.neg(t2);
        let (g, stats) = simplified(&b);
        assert_eq!(stats.rewrites, 1);
        assert!(g.nodes.iter().all(|n| n.op != "Transpose"));
        // Non-cancelling perms stay.
        let mut b2 = GraphBuilder::new();
        let y = b2.placeholder("y", DType::F32).unwrap();
        let u1 = b2.transpose(y, vec![1, 2, 0]);
        let u2 = b2.transpose(u1, vec![1, 2, 0]);
        let _sink = b2.neg(u2);
        let (_, s2) = simplified(&b2);
        assert_eq!(s2.rewrites, 0);
        // Default (reverse) perms always cancel.
        let mut b3 = GraphBuilder::new();
        let z = b3.placeholder("z", DType::F32).unwrap();
        let v1 = b3.transpose(z, vec![]);
        let v2 = b3.transpose(v1, vec![]);
        let _sink = b3.neg(v2);
        let (_, s3) = simplified(&b3);
        assert_eq!(s3.rewrites, 1);
    }

    #[test]
    fn sink_identity_anchoring_stateful_subtree_not_bypassed() {
        // run_targets(Identity(AssignAdd(v, 1))): bypassing the sink
        // Identity would prune the AssignAdd and lose the side effect.
        let mut b = GraphBuilder::new();
        let v = b.variable("v", Tensor::scalar_f32(0.0)).unwrap();
        let one = b.scalar(1.0);
        let upd = b.assign_add(v, one).unwrap();
        let _target = b.identity(Endpoint::new(upd, 0));
        let (g, stats) = simplified(&b);
        assert_eq!(stats.rewrites, 0);
        assert!(g.nodes.iter().any(|n| n.op == "AssignAdd"));
        assert!(g.nodes.iter().any(|n| n.op == "Identity"));
    }

    #[test]
    fn control_edges_block_rewrites() {
        let mut b = GraphBuilder::new();
        let x = b.placeholder("x", DType::F32).unwrap();
        let one = b.scalar(1.0);
        let m = b.mul(x, one);
        let trigger = b.no_op("trigger");
        b.add_control_input(m.node, trigger);
        let _sink = b.neg(m);
        let (g, stats) = simplified(&b);
        assert_eq!(stats.rewrites, 0);
        assert!(g.nodes.iter().any(|n| n.op == "Mul"));
    }

    #[test]
    fn shared_operand_survives() {
        // The scalar 1 is also consumed elsewhere: only the Mul dies.
        let mut b = GraphBuilder::new();
        let x = b.placeholder("x", DType::F32).unwrap();
        let one = b.scalar(1.0);
        let m = b.mul(x, one);
        let kept = b.add(one, one);
        let _sink = b.add(m, kept);
        let (g, stats) = simplified(&b);
        assert_eq!(stats.rewrites, 1);
        assert!(g.nodes.iter().any(|n| n.op == "Const"), "shared const dropped");
        assert!(g.nodes.iter().all(|n| n.op != "Mul"));
    }
}
