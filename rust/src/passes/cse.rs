//! §5.1 Common subexpression elimination: "a common subexpression pass
//! similar to the algorithm described by Click that runs over the
//! computation graph and canonicalizes multiple copies of operations with
//! identical inputs and operation types to just a single one of these
//! nodes, and redirects graph edges appropriately."
//!
//! Value-numbering over topological order. Stateful ops (Variables, queue
//! ops, random ops, Send/Recv) are never merged; nodes with device
//! constraints merge only with nodes constrained identically.

use crate::error::Result;
use crate::graph::{AttrValue, Graph, NodeId};
use crate::ops;
use std::collections::HashMap;

/// Statistics from one CSE run.
#[derive(Debug, Default, Clone, PartialEq)]
pub struct CseStats {
    pub nodes_before: usize,
    pub nodes_removed: usize,
}

/// Run CSE in place: rewrites edges toward canonical nodes and drops the
/// duplicates. Returns the rewritten graph (pruning removes the dead
/// copies) and stats.
pub fn common_subexpression_elimination(graph: &Graph) -> Result<(Graph, CseStats)> {
    let order = graph.topo_order()?;
    // canonical[n] = representative for n.
    let mut canonical: Vec<NodeId> = graph.ids().collect();
    // value number key -> representative
    let mut table: HashMap<String, NodeId> = HashMap::new();

    for id in &order {
        let id = *id;
        let n = graph.node(id);
        let stateful = ops::lookup(&n.op).map(|d| d.stateful).unwrap_or(true);
        if stateful || n.op == "Placeholder" || n.op.starts_with('_') {
            continue;
        }
        // Key: op + canonicalized inputs + attrs + device constraint.
        let mut key = String::with_capacity(64);
        key.push_str(&n.op);
        key.push('(');
        for e in &n.inputs {
            key.push_str(&format!("{}:{},", canonical[e.node.0].0, e.port));
        }
        key.push(')');
        for c in &n.control_inputs {
            key.push_str(&format!("^{},", canonical[c.0].0));
        }
        key.push('[');
        for (k, v) in &n.attrs {
            key.push_str(k);
            key.push('=');
            attr_fingerprint(v, &mut key);
            key.push(',');
        }
        key.push(']');
        key.push('@');
        key.push_str(&n.requested_device);

        match table.get(&key) {
            Some(&rep) => canonical[id.0] = rep,
            None => {
                table.insert(key, id);
            }
        }
    }

    // Rewrite a copy of the graph with edges pointing at representatives,
    // then prune unreachable duplicates.
    let mut rewritten = graph.clone();
    for id in rewritten.ids().collect::<Vec<_>>() {
        let inputs: Vec<_> = rewritten
            .node(id)
            .inputs
            .iter()
            .map(|e| crate::graph::Endpoint::new(canonical[e.node.0], e.port))
            .collect();
        let controls: Vec<_> =
            rewritten.node(id).control_inputs.iter().map(|c| canonical[c.0]).collect();
        let n = rewritten.node_mut(id);
        n.inputs = inputs;
        n.control_inputs = controls;
    }
    // Keep only nodes that are their own canonical representative.
    let keep: std::collections::HashSet<NodeId> =
        graph.ids().filter(|id| canonical[id.0] == *id).collect();
    let removed = graph.len() - keep.len();
    let (pruned, _) = rewritten.subgraph(&keep);
    Ok((pruned, CseStats { nodes_before: graph.len(), nodes_removed: removed }))
}

fn attr_fingerprint(v: &AttrValue, out: &mut String) {
    use std::fmt::Write;
    match v {
        AttrValue::I64(x) => {
            let _ = write!(out, "i{x}");
        }
        AttrValue::F32(x) => {
            let _ = write!(out, "f{}", x.to_bits());
        }
        AttrValue::Bool(x) => {
            let _ = write!(out, "b{x}");
        }
        AttrValue::Str(s) => {
            let _ = write!(out, "s{s}");
        }
        AttrValue::Type(t) => {
            let _ = write!(out, "t{t}");
        }
        AttrValue::Shape(s) => {
            let _ = write!(out, "S{s}");
        }
        AttrValue::Tensor(t) => {
            // Fingerprint contents: constants with equal values merge.
            let _ = write!(out, "T{}{}", t.dtype(), t.shape());
            if let Ok(v) = t.as_f32() {
                for x in v.iter().take(64) {
                    let _ = write!(out, ",{}", x.to_bits());
                }
                let _ = write!(out, ";n{}", v.len());
            } else {
                // Non-f32 constants: conservative — unique key.
                let _ = write!(out, "?{:p}", t.data() as *const _);
            }
        }
        AttrValue::ListI64(xs) => {
            let _ = write!(out, "LI{xs:?}");
        }
        AttrValue::ListStr(xs) => {
            let _ = write!(out, "LS{xs:?}");
        }
        AttrValue::ListType(xs) => {
            let _ = write!(out, "LT{xs:?}");
        }
        AttrValue::ListShape(xs) => {
            let _ = write!(out, "Lh{}", xs.len());
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ops::builder::GraphBuilder;
    use crate::tensor::Tensor;

    #[test]
    fn merges_identical_subexpressions() {
        let mut b = GraphBuilder::new();
        let x = b.placeholder("x", crate::tensor::DType::F32).unwrap();
        // Two layers of abstraction both computed x*x (the paper's
        // motivating case: redundancy from layered client code).
        let sq1 = b.mul(x, x);
        let sq2 = b.mul(x, x);
        let _ = b.add(sq1, sq2);
        let (g, stats) = common_subexpression_elimination(&b.graph).unwrap();
        assert_eq!(stats.nodes_removed, 1);
        assert_eq!(g.len(), b.graph.len() - 1);
        // Add must now read the same Mul twice.
        let add = g.nodes.iter().find(|n| n.op == "Add").unwrap();
        assert_eq!(add.inputs[0].node, add.inputs[1].node);
    }

    #[test]
    fn cascading_merges() {
        let mut b = GraphBuilder::new();
        let x = b.placeholder("x", crate::tensor::DType::F32).unwrap();
        // Duplicate chains: neg(neg(x)) twice -> should collapse fully.
        let a1 = b.neg(x);
        let a2 = b.neg(a1);
        let b1 = b.neg(x);
        let b2 = b.neg(b1);
        let _ = b.add(a2, b2);
        let (_, stats) = common_subexpression_elimination(&b.graph).unwrap();
        assert_eq!(stats.nodes_removed, 2);
    }

    #[test]
    fn identical_constants_merge() {
        let mut b = GraphBuilder::new();
        let c1 = b.scalar(3.0);
        let c2 = b.scalar(3.0);
        let c3 = b.scalar(4.0);
        let s = b.add(c1, c2);
        let _ = b.add(s, c3);
        let (_, stats) = common_subexpression_elimination(&b.graph).unwrap();
        assert_eq!(stats.nodes_removed, 1); // only the duplicate 3.0
    }

    #[test]
    fn stateful_ops_never_merged() {
        let mut b = GraphBuilder::new();
        let v1 = b.variable("v1", Tensor::scalar_f32(0.0)).unwrap();
        let v2 = b.variable("v2", Tensor::scalar_f32(0.0)).unwrap();
        let _ = b.add(v1, v2);
        let before = b.graph.len();
        let (g, stats) = common_subexpression_elimination(&b.graph).unwrap();
        // The two identical init constants (0.0) may merge; Variables and
        // Assigns must not.
        assert_eq!(
            g.nodes.iter().filter(|n| n.op == "Variable").count(),
            2,
            "variables merged!"
        );
        assert_eq!(g.nodes.iter().filter(|n| n.op == "Assign").count(), 2);
        assert!(stats.nodes_removed <= before - 6);
    }

    #[test]
    fn different_attrs_not_merged() {
        let mut b = GraphBuilder::new();
        let x = b.placeholder("x", crate::tensor::DType::F32).unwrap();
        let t1 = b.matmul_t(x, x, false, false);
        let t2 = b.matmul_t(x, x, true, false);
        let _ = b.add(t1, t2);
        let (_, stats) = common_subexpression_elimination(&b.graph).unwrap();
        assert_eq!(stats.nodes_removed, 0);
    }

    #[test]
    fn different_device_constraints_not_merged() {
        let mut b = GraphBuilder::new();
        let x = b.placeholder("x", crate::tensor::DType::F32).unwrap();
        let a = b.with_device("/device:cpu:0", |b| b.neg(x));
        let c = b.with_device("/device:cpu:1", |b| b.neg(x));
        let _ = b.add(a, c);
        let (_, stats) = common_subexpression_elimination(&b.graph).unwrap();
        assert_eq!(stats.nodes_removed, 0);
    }
}
