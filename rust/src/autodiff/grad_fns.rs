//! Built-in gradient functions (§4.1: "the newly added node computes the
//! 'gradient function' for the corresponding operation in the forward
//! path"). Each receives the forward node (for access to its inputs and
//! outputs, as the paper allows) and the output gradients.
//!
//! Broadcasting ops reduce their gradients back to the operand shapes via
//! the runtime `SumToShape` op, since shapes are unknown at
//! graph-construction time.

use super::GradFn;
#[allow(unused_imports)]
use crate::error::Result;
use crate::graph::{AttrValue, Endpoint, NodeId};
use crate::ops::builder::GraphBuilder;
use std::collections::HashMap;

fn inputs(b: &GraphBuilder, node: NodeId) -> Vec<Endpoint> {
    b.graph.node(node).inputs.clone()
}

fn out(node: NodeId, port: usize) -> Endpoint {
    Endpoint::new(node, port)
}

/// g reduced to the shape of `like`.
fn sum_to(b: &mut GraphBuilder, g: Endpoint, like: Endpoint) -> Endpoint {
    b.op1("SumToShape", "SumToShape", vec![g, like], vec![]).unwrap()
}

pub(super) fn install(m: &mut HashMap<&'static str, GradFn>) {
    m.insert("Add", |b, node, gs| {
        let ins = inputs(b, node);
        let g = gs[0].unwrap();
        Ok(vec![Some(sum_to(b, g, ins[0])), Some(sum_to(b, g, ins[1]))])
    });
    m.insert("Sub", |b, node, gs| {
        let ins = inputs(b, node);
        let g = gs[0].unwrap();
        let ng = b.neg(g);
        Ok(vec![Some(sum_to(b, g, ins[0])), Some(sum_to(b, ng, ins[1]))])
    });
    m.insert("Mul", |b, node, gs| {
        let ins = inputs(b, node);
        let g = gs[0].unwrap();
        let ga = b.mul(g, ins[1]);
        let gb = b.mul(g, ins[0]);
        Ok(vec![Some(sum_to(b, ga, ins[0])), Some(sum_to(b, gb, ins[1]))])
    });
    m.insert("Div", |b, node, gs| {
        // d(a/b) = g/b, -g·a/b²
        let ins = inputs(b, node);
        let g = gs[0].unwrap();
        let ga = b.div(g, ins[1]);
        let b2 = b.square(ins[1]);
        let a_over_b2 = b.div(ins[0], b2);
        let gb0 = b.mul(g, a_over_b2);
        let gb = b.neg(gb0);
        Ok(vec![Some(sum_to(b, ga, ins[0])), Some(sum_to(b, gb, ins[1]))])
    });
    m.insert("Maximum", |b, node, gs| {
        // Route gradient to the larger operand.
        let ins = inputs(b, node);
        let g = gs[0].unwrap();
        let take_a = b.op1("GreaterEqual", "ge", vec![ins[0], ins[1]], vec![]).unwrap();
        let zero = b.zeros_like(g);
        let ga = b.select(take_a, g, zero);
        let gb = b.select(take_a, zero, g);
        Ok(vec![Some(sum_to(b, ga, ins[0])), Some(sum_to(b, gb, ins[1]))])
    });
    m.insert("Neg", |b, _node, gs| {
        let g = gs[0].unwrap();
        Ok(vec![Some(b.neg(g))])
    });
    m.insert("Exp", |b, node, gs| {
        // d exp(x) = g * exp(x) — reuse the forward output (§4.1 allows
        // gradient functions to consume forward outputs).
        let g = gs[0].unwrap();
        Ok(vec![Some(b.mul(g, out(node, 0)))])
    });
    m.insert("Log", |b, node, gs| {
        let ins = inputs(b, node);
        let g = gs[0].unwrap();
        Ok(vec![Some(b.div(g, ins[0]))])
    });
    m.insert("Sqrt", |b, node, gs| {
        // d sqrt = g / (2 sqrt(x))
        let g = gs[0].unwrap();
        let two = b.scalar(2.0);
        let denom = b.mul(two, out(node, 0));
        Ok(vec![Some(b.div(g, denom))])
    });
    m.insert("Square", |b, node, gs| {
        let ins = inputs(b, node);
        let g = gs[0].unwrap();
        let two = b.scalar(2.0);
        let tx = b.mul(two, ins[0]);
        Ok(vec![Some(b.mul(g, tx))])
    });
    m.insert("Tanh", |b, node, gs| {
        // d tanh = g (1 - tanh²)
        let g = gs[0].unwrap();
        let y2 = b.square(out(node, 0));
        let one = b.scalar(1.0);
        let d = b.sub(one, y2);
        Ok(vec![Some(b.mul(g, d))])
    });
    m.insert("Sigmoid", |b, node, gs| {
        // d σ = g σ (1-σ)
        let g = gs[0].unwrap();
        let y = out(node, 0);
        let one = b.scalar(1.0);
        let om = b.sub(one, y);
        let d = b.mul(y, om);
        Ok(vec![Some(b.mul(g, d))])
    });
    m.insert("Reciprocal", |b, node, gs| {
        // d (1/x) = -g / x² = -g·y²
        let g = gs[0].unwrap();
        let y2 = b.square(out(node, 0));
        let gy = b.mul(g, y2);
        Ok(vec![Some(b.neg(gy))])
    });
    m.insert("Abs", |b, node, gs| {
        let ins = inputs(b, node);
        let g = gs[0].unwrap();
        let s = b.op1("Sign", "sign", vec![ins[0]], vec![]).unwrap();
        Ok(vec![Some(b.mul(g, s))])
    });
    m.insert("Identity", |_b, _node, gs| Ok(vec![gs[0]]));
    m.insert("_Feed", |_b, _node, _gs| Ok(vec![]));
    m.insert("AddN", |b, node, gs| {
        let n = inputs(b, node).len();
        Ok(vec![gs[0]; n])
    });
    m.insert("MatMul", |b, node, gs| {
        let ins = inputs(b, node);
        let g = gs[0].unwrap();
        let n = b.graph.node(node);
        let ta = n.attrs.get("transpose_a").and_then(|a| a.as_bool().ok()).unwrap_or(false);
        let tb = n.attrs.get("transpose_b").and_then(|a| a.as_bool().ok()).unwrap_or(false);
        let (a, bb) = (ins[0], ins[1]);
        let (da, db) = match (ta, tb) {
            (false, false) => (b.matmul_t(g, bb, false, true), b.matmul_t(a, g, true, false)),
            (false, true) => (b.matmul_t(g, bb, false, false), b.matmul_t(g, a, true, false)),
            (true, false) => (b.matmul_t(bb, g, false, true), b.matmul_t(a, g, false, false)),
            (true, true) => (b.matmul_t(bb, g, true, true), b.matmul_t(g, a, true, true)),
        };
        Ok(vec![Some(da), Some(db)])
    });
    m.insert("ReLU", |b, node, gs| {
        let ins = inputs(b, node);
        let g = gs[0].unwrap();
        Ok(vec![Some(b.op1("ReluGrad", "ReluGrad", vec![g, ins[0]], vec![])?)])
    });
    m.insert("BiasAdd", |b, node, gs| {
        let _ = node;
        let g = gs[0].unwrap();
        let db = b.op1("BiasAddGrad", "BiasAddGrad", vec![g], vec![])?;
        Ok(vec![Some(g), Some(db)])
    });
    m.insert("Sum", |b, node, gs| {
        let ins = inputs(b, node);
        let g = gs[0].unwrap();
        Ok(vec![Some(b.op1("BroadcastLike", "bcast", vec![g, ins[0]], vec![])?)])
    });
    m.insert("Mean", |b, node, gs| {
        // d mean = broadcast(g) * (size(out)/size(in))
        let ins = inputs(b, node);
        let g = gs[0].unwrap();
        let bg = b.op1("BroadcastLike", "bcast", vec![g, ins[0]], vec![])?;
        let size_in = b.op1("Size", "size_in", vec![ins[0]], vec![])?;
        let size_out = b.op1("Size", "size_out", vec![out(node, 0)], vec![])?;
        let fin = b.cast(size_in, crate::tensor::DType::F32);
        let fout = b.cast(size_out, crate::tensor::DType::F32);
        let scale = b.div(fout, fin);
        Ok(vec![Some(b.mul(bg, scale))])
    });
    m.insert("SoftmaxCrossEntropyWithLogits", |b, node, gs| {
        // d loss/d logits = backprop (port 1) scaled by g (per-row). The
        // labels input gets no gradient.
        let g = gs[0].unwrap(); // grad of loss vector [batch]
        let backprop = out(node, 1);
        // Scale rows: reshape g to [batch,1] and broadcast-multiply.
        let gcol = b.op1("ExpandDims", "expand", vec![g], vec![("axis", AttrValue::I64(1))])?;
        let scaled = b.mul(backprop, gcol);
        Ok(vec![Some(scaled), None])
    });
    m.insert("SoftMax", |b, node, gs| {
        // d softmax: y * (g - sum(g*y, axis=-1, keepdims))
        let g = gs[0].unwrap();
        let y = out(node, 0);
        let gy = b.mul(g, y);
        let s = b.reduce_sum(gy, Some(vec![-1]));
        let scol = b.op1("ExpandDims", "expand", vec![s], vec![("axis", AttrValue::I64(-1))])?;
        let diff = b.sub(g, scol);
        Ok(vec![Some(b.mul(y, diff))])
    });
    m.insert("LogSoftmax", |b, node, gs| {
        // d logsoftmax = g - softmax(x) * sum(g)
        let ins = inputs(b, node);
        let g = gs[0].unwrap();
        let sm = b.softmax(ins[0]);
        let s = b.reduce_sum(g, Some(vec![-1]));
        let scol = b.op1("ExpandDims", "expand", vec![s], vec![("axis", AttrValue::I64(-1))])?;
        let scaled = b.mul(sm, scol);
        Ok(vec![Some(b.sub(g, scaled))])
    });
    m.insert("L2Loss", |b, node, gs| {
        let ins = inputs(b, node);
        let g = gs[0].unwrap();
        Ok(vec![Some(b.mul(g, ins[0]))])
    });
    m.insert("Reshape", |b, node, gs| {
        let ins = inputs(b, node);
        let g = gs[0].unwrap();
        // Reshape back to the input's (runtime) shape.
        let back = b.op1("ReshapeLike", "unshape", vec![g, ins[0]], vec![])?;
        Ok(vec![Some(back), None])
    });
    m.insert("Transpose", |b, node, gs| {
        let g = gs[0].unwrap();
        let n = b.graph.node(node);
        let perm: Vec<i64> = n
            .attrs
            .get("perm")
            .and_then(|a| a.as_list_i64().ok().map(|s| s.to_vec()))
            .unwrap_or_default();
        let inv = if perm.is_empty() {
            vec![]
        } else {
            let mut inv = vec![0i64; perm.len()];
            for (i, &p) in perm.iter().enumerate() {
                inv[p as usize] = i as i64;
            }
            inv
        };
        Ok(vec![Some(b.transpose(g, inv))])
    });
    m.insert("Concat", |b, node, gs| {
        // Split the gradient back along the axis. Requires equal-size
        // parts (our Split), which covers the library's own uses.
        let ins = inputs(b, node);
        let g = gs[0].unwrap();
        let n = b.graph.node(node);
        let axis = n.attrs.get("axis").and_then(|a| a.as_i64().ok()).unwrap_or(0);
        let parts = b.split(g, axis, ins.len() as i64)?;
        Ok(parts.into_iter().map(Some).collect())
    });
    m.insert("Pack", |b, node, gs| {
        let ins = inputs(b, node);
        let g = gs[0].unwrap();
        let n = b.graph.node(node);
        let axis = n.attrs.get("axis").and_then(|a| a.as_i64().ok()).unwrap_or(0);
        let parts = b.split(g, axis, ins.len() as i64)?;
        // Each part keeps a 1-dim at `axis`: collapse via SumToShape.
        Ok(parts
            .into_iter()
            .zip(ins)
            .map(|(p, i)| Some(sum_to(b, p, i)))
            .collect())
    });
    m.insert("ExpandDims", |b, node, gs| {
        let ins = inputs(b, node);
        let g = gs[0].unwrap();
        Ok(vec![Some(b.op1("ReshapeLike", "unexpand", vec![g, ins[0]], vec![])?)])
    });
    m.insert("Squeeze", |b, node, gs| {
        let ins = inputs(b, node);
        let g = gs[0].unwrap();
        Ok(vec![Some(b.op1("ReshapeLike", "unsqueeze", vec![g, ins[0]], vec![])?)])
    });
    m.insert("Convolution2D", |b, node, gs| {
        let ins = inputs(b, node);
        let g = gs[0].unwrap();
        let n = b.graph.node(node);
        let stride = n.attrs.get("stride").and_then(|a| a.as_i64().ok()).unwrap_or(1);
        let padding = n
            .attrs
            .get("padding")
            .and_then(|a| a.as_str().ok().map(String::from))
            .unwrap_or_else(|| "SAME".into());
        let attrs = vec![("stride", stride.into()), ("padding", padding.as_str().into())];
        let dx = b.op1("Conv2DBackpropInput", "conv_dx", vec![g, ins[1], ins[0]], attrs.clone())?;
        let df = b.op1("Conv2DBackpropFilter", "conv_df", vec![ins[0], g, ins[1]], attrs)?;
        Ok(vec![Some(dx), Some(df)])
    });
    m.insert("MaxPool", |b, node, gs| {
        let ins = inputs(b, node);
        let g = gs[0].unwrap();
        let argmax = out(node, 1);
        // Forward the window geometry (with the forward kernel's
        // defaults baked in) so MaxPoolGrad can run its parallel
        // gather form — it reconstructs each input element's covering
        // windows from ksize/stride/padding.
        let n = b.graph.node(node);
        let ksize = n.attrs.get("ksize").and_then(|a| a.as_i64().ok()).unwrap_or(2);
        let stride = n.attrs.get("stride").and_then(|a| a.as_i64().ok()).unwrap_or(1);
        let padding = n
            .attrs
            .get("padding")
            .and_then(|a| a.as_str().ok().map(String::from))
            .unwrap_or_else(|| "SAME".into());
        let attrs = vec![
            ("ksize", ksize.into()),
            ("stride", stride.into()),
            ("padding", padding.as_str().into()),
        ];
        let dx = b.op1("MaxPoolGrad", "pool_dx", vec![g, argmax, ins[0]], attrs)?;
        Ok(vec![Some(dx)])
    });
    m.insert("Gather", |b, node, gs| {
        // d params is an IndexedSlices (§4.2's sparse gradients): rows
        // `indices` of the params receive the matching rows of g — never
        // a dense zeros-like of the table. The returned endpoint is a
        // *lazy* SparseToDense node whose (indices, values) twins are
        // recorded in `b.sparse_grads`; sparse-aware consumers fetch the
        // twins and the densify never executes.
        let ins = inputs(b, node);
        let g = gs[0].unwrap();
        let idx = b.cast(ins[1], crate::tensor::DType::I64);
        let handle = b.op1("SparseToDense", "gather_grad", vec![idx, g, ins[0]], vec![])?;
        b.sparse_grads
            .insert(handle, crate::sparse::IndexedSlices { indices: idx, values: g });
        Ok(vec![Some(handle), None])
    });
    m.insert("UnsortedSegmentSum", |b, node, gs| {
        // d data[k] = g[segment_ids[k]].
        let ins = inputs(b, node);
        let g = gs[0].unwrap();
        Ok(vec![Some(b.op1("Gather", "seg_grad", vec![g, ins[1]], vec![])?), None])
    });
    m.insert("ScatterAdd", |b, node, gs| {
        // out = x + scatter(updates): dx = g, dupdates = g[indices].
        let ins = inputs(b, node);
        let g = gs[0].unwrap();
        let du = b.op1("Gather", "scatter_grad", vec![g, ins[1]], vec![])?;
        Ok(vec![Some(g), None, Some(du)])
    });
    m.insert("ScatterSub", |b, node, gs| {
        let ins = inputs(b, node);
        let g = gs[0].unwrap();
        let du = b.op1("Gather", "scatter_grad", vec![g, ins[1]], vec![])?;
        Ok(vec![Some(g), None, Some(b.neg(du))])
    });
    m.insert("DynamicPartition", |b, node, gs| {
        // Stitch the per-partition gradients back into data order by
        // partitioning the row ids the same way; partitions nothing was
        // fetched from densify to zeros so the stitch covers every row.
        let ins = inputs(b, node);
        let n_parts = gs.len() as i64;
        let rows = b.op1("RowIds", "rowids", vec![ins[0]], vec![])?;
        let id_parts = b.op(
            "DynamicPartition",
            "part_rows",
            vec![rows, ins[1]],
            vec![("num_partitions", n_parts.into())],
        )?;
        let mut stitch_in: Vec<Endpoint> =
            (0..gs.len()).map(|k| Endpoint::new(id_parts, k)).collect();
        for (k, gk) in gs.iter().enumerate() {
            stitch_in.push(crate::autodiff::grad_or_zeros(b, out(node, k), *gk));
        }
        let d = b.op1("DynamicStitch", "unpartition", stitch_in, vec![("N", n_parts.into())])?;
        Ok(vec![Some(d), None])
    });
    m.insert("DynamicStitch", |b, node, gs| {
        // d data_k = g[indices_k]. Exact when the stitch indices are a
        // permutation (as in sharded lookups); duplicate indices are
        // last-wins forward, so overwritten rows would be over-credited.
        let ins = inputs(b, node);
        let n = ins.len() / 2;
        let g = gs[0].unwrap();
        let mut grads: Vec<Option<Endpoint>> = vec![None; n];
        for &idx in ins.iter().take(n) {
            grads.push(Some(b.op1("Gather", "stitch_grad", vec![g, idx], vec![])?));
        }
        Ok(grads)
    });
    m.insert("SampledSoftmax", |b, node, gs| {
        // Fused grad kernel re-draws the step's negatives and returns
        // (demb dense, dweights as indices+values). The weights gradient
        // rides the IndexedSlices path like Gather's.
        let ins = inputs(b, node);
        let g = gs[0].unwrap();
        let n = b.graph.node(node);
        let num_sampled =
            n.attrs.get("num_sampled").and_then(|a| a.as_i64().ok()).unwrap_or(1);
        let seed = n.attrs.get("seed").and_then(|a| a.as_i64().ok()).unwrap_or(0);
        let gid = b.op(
            "SampledSoftmaxGrad",
            "sampled_softmax_grad",
            vec![ins[0], ins[1], ins[2], g],
            vec![("num_sampled", num_sampled.into()), ("seed", seed.into())],
        )?;
        let demb = Endpoint::new(gid, 0);
        let idx = Endpoint::new(gid, 1);
        let vals = Endpoint::new(gid, 2);
        let handle = b.op1("SparseToDense", "dweights", vec![idx, vals, ins[1]], vec![])?;
        b.sparse_grads
            .insert(handle, crate::sparse::IndexedSlices { indices: idx, values: vals });
        Ok(vec![Some(demb), Some(handle), None])
    });
    m.insert("Select", |b, node, gs| {
        let ins = inputs(b, node);
        let g = gs[0].unwrap();
        let zero = b.zeros_like(g);
        let ga = b.select(ins[0], g, zero);
        let gb = b.select(ins[0], zero, g);
        Ok(vec![None, Some(ga), Some(gb)])
    });
    m.insert("Cast", |_b, _node, gs| Ok(vec![gs[0]]));
    m.insert("CheckNumerics", |_b, _node, gs| Ok(vec![gs[0]]));
    m.insert("Print", |_b, _node, gs| Ok(vec![gs[0]]));
    m.insert("ZerosLike", |_b, _node, _gs| Ok(vec![None]));
    m.insert("OnesLike", |_b, _node, _gs| Ok(vec![None]));
    m.insert("Shape", |_b, _node, _gs| Ok(vec![None]));
    m.insert("Size", |_b, _node, _gs| Ok(vec![None]));
    m.insert("Rank", |_b, _node, _gs| Ok(vec![None]));
    m.insert("Const", |_b, _node, _gs| Ok(vec![]));
    m.insert("Placeholder", |_b, _node, _gs| Ok(vec![]));
    m.insert("Variable", |_b, _node, _gs| Ok(vec![]));
    m.insert("BroadcastLike", |b, node, gs| {
        let ins = inputs(b, node);
        let g = gs[0].unwrap();
        Ok(vec![Some(sum_to(b, g, ins[0])), None])
    });
    m.insert("SumToShape", |b, node, gs| {
        let ins = inputs(b, node);
        let g = gs[0].unwrap();
        Ok(vec![Some(b.op1("BroadcastLike", "bcast", vec![g, ins[0]], vec![])?), None])
    });
}
