//! Automatic gradient computation (§4.1).
//!
//! "When TensorFlow needs to compute the gradient of a tensor C with
//! respect to some tensor I on which C depends, it first finds the path in
//! the computation graph from I to C. Then it backtracks from C to I, and
//! for each operation on the backward path it adds a node to the
//! TensorFlow graph, composing the partial gradients along the backwards
//! path using the chain rule. … A gradient function may be registered by
//! any operation. This function takes as input not only the partial
//! gradients computed already along the backward path, but also,
//! optionally, the inputs and outputs of the forward operation."
//!
//! Gradients are *graph extension*: `gradients()` appends nodes to the
//! builder's graph and returns the `dC/dx` endpoints. Unused outputs get
//! zero gradients ("the first input to O's gradient function is set to 0
//! since dC/dy1 = 0" — represented as `None` and materialized as
//! ZerosLike only when a gradient function needs them).

pub mod grad_fns;

use crate::error::{Result, Status};
use crate::graph::{Endpoint, NodeId};
use crate::ops::builder::GraphBuilder;
use std::sync::LazyLock as Lazy;
use std::collections::{HashMap, HashSet};
use std::sync::RwLock;

/// A gradient function: given the forward node and the gradients of its
/// outputs (None = zero), produce gradients for each input (None = no
/// gradient / not differentiable).
pub type GradFn = fn(
    b: &mut GraphBuilder,
    node: NodeId,
    grad_outputs: &[Option<Endpoint>],
) -> Result<Vec<Option<Endpoint>>>;

static GRAD_REGISTRY: Lazy<RwLock<HashMap<&'static str, GradFn>>> = Lazy::new(|| {
    let mut m = HashMap::new();
    grad_fns::install(&mut m);
    RwLock::new(m)
});

/// Register a gradient function for an op ("a gradient function may be
/// registered by any operation").
pub fn register_gradient(op: &'static str, f: GradFn) {
    GRAD_REGISTRY.write().unwrap().insert(op, f);
}

pub fn has_gradient(op: &str) -> bool {
    GRAD_REGISTRY.read().unwrap().contains_key(op)
}

/// Sum several partial gradients targeting one forward endpoint. When
/// *every* part is an `IndexedSlices` (per `b.sparse_grads`), the sum
/// stays sparse: concat the indices and values row-wise (duplicates mean
/// "sum" downstream) and register a combined lazy densify handle. Any
/// dense part forces `AddN` over the dense handles.
fn accumulate(b: &mut GraphBuilder, parts: &[Endpoint]) -> Endpoint {
    if parts.len() == 1 {
        return parts[0];
    }
    let sparse: Option<Vec<crate::sparse::IndexedSlices>> =
        parts.iter().map(|p| b.sparse_grads.get(p).copied()).collect();
    if let Some(slices) = sparse {
        // Every part is a SparseToDense handle over the same forward
        // tensor, so part 0's `like` input serves the combined handle.
        let like = b.graph.node(parts[0].node).inputs[2];
        let idx = b.concat(slices.iter().map(|s| s.indices).collect(), 0);
        let vals = b.concat(slices.iter().map(|s| s.values).collect(), 0);
        let handle = b
            .op1("SparseToDense", "sparse_accum", vec![idx, vals, like], vec![])
            .expect("SparseToDense arity is fixed");
        b.sparse_grads
            .insert(handle, crate::sparse::IndexedSlices { indices: idx, values: vals });
        return handle;
    }
    b.add_n(parts.to_vec())
}

/// Compute symbolic gradients of (scalar-ish) `y` w.r.t. each of `xs` by
/// extending the graph. Returns one endpoint per x (None when y does not
/// depend on x).
pub fn gradients(
    b: &mut GraphBuilder,
    y: Endpoint,
    xs: &[Endpoint],
) -> Result<Vec<Option<Endpoint>>> {
    // Forward-reachable set from each x …
    let fanout = b.graph.fanout();
    let mut from_xs: HashSet<NodeId> = HashSet::new();
    let mut stack: Vec<NodeId> = xs.iter().map(|e| e.node).collect();
    while let Some(n) = stack.pop() {
        if !from_xs.insert(n) {
            continue;
        }
        for &(c, _) in &fanout.data[n.0] {
            stack.push(c);
        }
    }
    // … intersected with the backward-reachable set from y: the nodes on
    // paths from I to C (§4.1).
    let to_y = b.graph.reachable_from(&[y.node]);
    let on_path: HashSet<NodeId> = from_xs.intersection(&to_y).copied().collect();
    if !on_path.contains(&y.node) {
        // y independent of all xs.
        return Ok(xs.iter().map(|_| None).collect());
    }

    // Accumulated partial gradients per forward endpoint.
    let mut grads: HashMap<Endpoint, Vec<Endpoint>> = HashMap::new();
    let seed = b.ones_like(y);
    grads.insert(y, vec![seed]);

    // Backward pass in reverse topological order over on-path nodes.
    let order = b.graph.topo_order()?;
    for &node_id in order.iter().rev() {
        if !on_path.contains(&node_id) {
            continue;
        }
        let node = b.graph.node(node_id);
        let op = node.op.clone();
        let num_outputs = crate::ops::num_outputs(node)?;
        let inputs = node.inputs.clone();
        // Collect dC/d(output_port) for every port, summing multiple
        // contributions with AddN.
        let mut grad_outputs: Vec<Option<Endpoint>> = Vec::with_capacity(num_outputs);
        let mut any = false;
        for port in 0..num_outputs {
            let ep = Endpoint::new(node_id, port);
            match grads.get(&ep) {
                Some(parts) => {
                    let sum = accumulate(b, &parts.clone());
                    grad_outputs.push(Some(sum));
                    any = true;
                }
                None => grad_outputs.push(None),
            }
        }
        if !any {
            continue; // no gradient flows through this node
        }
        if op == "StopGradient" {
            continue; // blocks flow by definition
        }
        let f = {
            let reg = GRAD_REGISTRY.read().unwrap();
            reg.get(op.as_str()).copied()
        };
        let f = f.ok_or_else(|| {
            Status::unimplemented(format!(
                "no gradient registered for op {op:?} (node {})",
                b.graph.node(node_id).name
            ))
        })?;
        let input_grads = b.with_scope("gradients", |b| f(b, node_id, &grad_outputs))?;
        if input_grads.len() != inputs.len() {
            return Err(Status::internal(format!(
                "gradient of {op} returned {} grads for {} inputs",
                input_grads.len(),
                inputs.len()
            )));
        }
        for (edge, g) in inputs.iter().zip(input_grads) {
            if let Some(g) = g {
                // Only accumulate toward nodes on the path (others are
                // constants w.r.t. xs — keeping their grads would drag in
                // dead subgraphs).
                if on_path.contains(&edge.node) {
                    grads.entry(*edge).or_default().push(g);
                }
            }
        }
    }

    Ok(xs
        .iter()
        .map(|x| grads.get(x).map(|parts| accumulate(b, &parts.clone())))
        .collect())
}

/// Materialize a possibly-missing output gradient as zeros-like the
/// forward endpoint (§4.1's "set to 0").
pub fn grad_or_zeros(b: &mut GraphBuilder, fw: Endpoint, g: Option<Endpoint>) -> Endpoint {
    match g {
        Some(g) => g,
        None => b.zeros_like(fw),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::session::{Session, SessionOptions};
    use crate::tensor::{DType, Tensor};

    /// Numerically check dy/dx at `x0` against the symbolic gradient.
    fn check_grad(
        build: impl Fn(&mut GraphBuilder, Endpoint) -> Endpoint,
        x0: Tensor,
        tol: f64,
    ) {
        let mut b = GraphBuilder::new();
        let x = b.placeholder("x", DType::F32).unwrap();
        let y = build(&mut b, x);
        // Reduce to scalar for well-defined FD.
        let loss = b.reduce_sum(y, None);
        let g = gradients(&mut b, loss, &[x]).unwrap()[0].expect("x should have grad");
        let gname = format!("{}:{}", b.graph.node(g.node).name, g.port);
        let lname = format!("{}:{}", b.graph.node(loss.node).name, loss.port);
        let sess = Session::new(b.into_graph(), SessionOptions::default());

        let analytic = sess.run(&[("x", x0.clone())], &[&gname], &[]).unwrap()[0].clone();
        let eps = 1e-3f32;
        let base = sess.run(&[("x", x0.clone())], &[&lname], &[]).unwrap()[0]
            .scalar_value_f32()
            .unwrap();
        let xv = x0.as_f32().unwrap().to_vec();
        for i in 0..xv.len() {
            let mut pert = xv.clone();
            pert[i] += eps;
            let xp = Tensor::from_f32(x0.shape().clone(), pert).unwrap();
            let lp = sess.run(&[("x", xp)], &[&lname], &[]).unwrap()[0]
                .scalar_value_f32()
                .unwrap();
            let fd = ((lp - base) / eps) as f64;
            let an = analytic.as_f32().unwrap()[i] as f64;
            assert!(
                (fd - an).abs() < tol * (1.0 + an.abs()),
                "grad[{i}]: fd={fd} analytic={an}"
            );
        }
    }

    #[test]
    fn grad_of_square() {
        check_grad(|b, x| b.square(x), Tensor::from_f32(vec![3], vec![1., -2., 0.5]).unwrap(), 1e-2);
    }

    #[test]
    fn grad_of_chain() {
        // d/dx sum(exp(2x)) = 2 exp(2x)
        check_grad(
            |b, x| {
                let two = b.scalar(2.0);
                let m = b.mul(x, two);
                b.exp(m)
            },
            Tensor::from_f32(vec![2], vec![0.1, -0.3]).unwrap(),
            1e-2,
        );
    }

    #[test]
    fn grad_of_tanh_sigmoid() {
        check_grad(
            |b, x| {
                let t = b.tanh(x);
                b.sigmoid(t)
            },
            Tensor::from_f32(vec![3], vec![0.2, -0.7, 1.3]).unwrap(),
            1e-2,
        );
    }

    #[test]
    fn grad_of_matmul_relu() {
        // The Fig 5 shape: grads through ReLU(W·x + b) — here wrt x.
        check_grad(
            |b, x| {
                let w = b
                    .constant(Tensor::from_f32(vec![3, 2], vec![0.5, -1., 2., 0.3, 1., 1.]).unwrap());
                let shape = b.constant(Tensor::from_i64(vec![2], vec![2, 2]).unwrap());
                let xm = b.op1("Reshape", "r", vec![x, shape], vec![]).unwrap();
                let mm = b.matmul(w, xm);
                b.relu(mm)
            },
            Tensor::from_f32(vec![4], vec![0.7, -0.2, 0.5, 1.1]).unwrap(),
            2e-2,
        );
    }

    #[test]
    fn grad_with_broadcast_bias() {
        // y = sum(x * c + bias) where bias broadcasts: checks SumToShape.
        check_grad(
            |b, x| {
                let c = b.constant(Tensor::from_f32(vec![2, 3], vec![1., 2., 3., 4., 5., 6.]).unwrap());
                let prod = b.mul(x, c);
                let bias = b.constant(Tensor::from_f32(vec![3], vec![1., 1., 1.]).unwrap());
                b.add(prod, bias)
            },
            Tensor::from_f32(vec![2, 3], vec![0.1; 6]).unwrap(),
            1e-2,
        );
    }

    #[test]
    fn grad_div_log() {
        check_grad(
            |b, x| {
                let c = b.scalar(3.0);
                let d = b.div(c, x);
                b.log(d)
            },
            Tensor::from_f32(vec![2], vec![1.5, 0.7]).unwrap(),
            1e-2,
        );
    }

    #[test]
    fn grad_mean() {
        check_grad(
            |b, x| b.reduce_mean(x, None),
            Tensor::from_f32(vec![4], vec![1., 2., 3., 4.]).unwrap(),
            1e-2,
        );
    }

    #[test]
    fn independent_returns_none() {
        let mut b = GraphBuilder::new();
        let x = b.placeholder("x", DType::F32).unwrap();
        let y = b.scalar(5.0);
        let g = gradients(&mut b, y, &[x]).unwrap();
        assert!(g[0].is_none());
    }

    #[test]
    fn stop_gradient_blocks() {
        let mut b = GraphBuilder::new();
        let x = b.placeholder("x", DType::F32).unwrap();
        let s = b.stop_gradient(x);
        let y = b.square(s);
        let g = gradients(&mut b, y, &[x]).unwrap();
        assert!(g[0].is_none(), "StopGradient must cut the path");
    }

    #[test]
    fn multiple_uses_accumulate() {
        // y = x*x + x  =>  dy/dx = 2x + 1
        let mut b = GraphBuilder::new();
        let x = b.placeholder("x", DType::F32).unwrap();
        let sq = b.mul(x, x);
        let y = b.add(sq, x);
        let g = gradients(&mut b, y, &[x]).unwrap()[0].unwrap();
        let gname = format!("{}:{}", b.graph.node(g.node).name, g.port);
        let sess = Session::new(b.into_graph(), SessionOptions::default());
        let out = sess.run(&[("x", Tensor::scalar_f32(3.0))], &[&gname], &[]).unwrap();
        assert!((out[0].scalar_value_f32().unwrap() - 7.0).abs() < 1e-5);
    }

    #[test]
    fn softmax_xent_gradient() {
        // Classic: d xent / d logits = softmax(logits) - labels.
        let mut b = GraphBuilder::new();
        let logits = b.placeholder("logits", DType::F32).unwrap();
        let labels = b.constant(Tensor::from_f32(vec![1, 3], vec![0., 1., 0.]).unwrap());
        let (loss_vec, _) = b.softmax_xent(logits, labels).unwrap();
        let loss = b.reduce_sum(loss_vec, None);
        let g = gradients(&mut b, loss, &[logits]).unwrap()[0].unwrap();
        let gname = format!("{}:{}", b.graph.node(g.node).name, g.port);
        let sess = Session::new(b.into_graph(), SessionOptions::default());
        let x0 = Tensor::from_f32(vec![1, 3], vec![1., 2., 0.5]).unwrap();
        let out = sess.run(&[("logits", x0.clone())], &[&gname], &[]).unwrap();
        let sm = crate::kernels::nn::softmax(&x0).unwrap();
        let expect: Vec<f32> = sm
            .as_f32()
            .unwrap()
            .iter()
            .zip([0f32, 1., 0.])
            .map(|(&p, y)| p - y)
            .collect();
        for (a, e) in out[0].as_f32().unwrap().iter().zip(expect) {
            assert!((a - e).abs() < 1e-5);
        }
    }
}
