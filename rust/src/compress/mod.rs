//! §5.5 lossy compression for cross-device/cross-machine tensor transfers:
//! "convert 32-bit floating point representations into a 16-bit floating
//! point representation (… a 32-bit IEEE 794 float format, but with 16
//! bits less precision in the mantissa), and then convert back … by just
//! filling in zeroes for the lost portion of the mantissa".
//!
//! That is precisely bf16-by-truncation: keep the upper 16 bits of the f32
//! (sign + 8 exponent + 7 mantissa bits), zero-fill on decode.

use crate::error::Result;
use crate::tensor::{Tensor, TensorData};

/// Truncate an f32 tensor to the bf16 wire format (upper 16 bits).
pub fn f32_to_bf16(t: &Tensor) -> Result<Tensor> {
    let v = t.as_f32()?;
    let out: Vec<u16> = v.iter().map(|&x| (x.to_bits() >> 16) as u16).collect();
    Tensor::new(t.shape().clone(), TensorData::BF16(out))
}

/// Expand a bf16 wire tensor back to f32 with zero-filled mantissa.
pub fn bf16_to_f32(t: &Tensor) -> Result<Tensor> {
    let v = t.as_bf16_raw()?;
    let out: Vec<f32> = v.iter().map(|&x| f32::from_bits((x as u32) << 16)).collect();
    Tensor::new(t.shape().clone(), TensorData::F32(out))
}

/// Worst-case relative error of the truncation: one ulp of a 7-bit
/// mantissa, i.e. 2^-7 (truncation loses up to a full ulp; rounding would
/// halve this — the paper explicitly chooses the cheaper truncation).
pub const MAX_RELATIVE_ERROR: f32 = 1.0 / 128.0;

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_error_bounded() {
        let vals: Vec<f32> = vec![
            0.0, 1.0, -1.0, 3.14159, -2.71828, 1e-20, 1e20, 123456.789, -0.000123,
        ];
        let t = Tensor::from_f32(vec![vals.len()], vals.clone()).unwrap();
        let rt = bf16_to_f32(&f32_to_bf16(&t).unwrap()).unwrap();
        for (&orig, &back) in vals.iter().zip(rt.as_f32().unwrap()) {
            if orig == 0.0 {
                assert_eq!(back, 0.0);
            } else {
                let rel = ((orig - back) / orig).abs();
                assert!(rel <= MAX_RELATIVE_ERROR, "orig={orig} back={back} rel={rel}");
            }
        }
    }

    #[test]
    fn halves_the_bytes() {
        let t = Tensor::from_f32(vec![100], vec![1.5; 100]).unwrap();
        let c = f32_to_bf16(&t).unwrap();
        assert_eq!(c.size_bytes(), t.size_bytes() / 2);
    }

    #[test]
    fn special_values_preserved() {
        let t = Tensor::from_f32(vec![3], vec![f32::INFINITY, f32::NEG_INFINITY, f32::NAN])
            .unwrap();
        let rt = bf16_to_f32(&f32_to_bf16(&t).unwrap()).unwrap();
        let v = rt.as_f32().unwrap();
        assert_eq!(v[0], f32::INFINITY);
        assert_eq!(v[1], f32::NEG_INFINITY);
        assert!(v[2].is_nan());
    }

    #[test]
    fn exact_for_small_integers() {
        // Integers up to 2^7 are exactly representable in 7 mantissa bits…
        for i in 0..=128 {
            let t = Tensor::scalar_f32(i as f32);
            let rt = bf16_to_f32(&f32_to_bf16(&t).unwrap()).unwrap();
            assert_eq!(rt.scalar_value_f32().unwrap(), i as f32);
        }
    }

    /// One round trip through the wire format.
    fn roundtrip1(x: f32) -> f32 {
        let t = Tensor::scalar_f32(x);
        bf16_to_f32(&f32_to_bf16(&t).unwrap()).unwrap().scalar_value_f32().unwrap()
    }

    #[test]
    fn property_random_bit_patterns() {
        // 200k uniformly random f32 bit patterns: the codec must uphold its
        // contract on every class of value, not just well-behaved ones.
        let mut rng = crate::util::rng::Pcg32::new(0x5eed);
        for _ in 0..200_000 {
            let bits = rng.next_u32();
            let x = f32::from_bits(bits);
            let back = roundtrip1(x);
            if x.is_nan() {
                // Truncation may drop a low-bit NaN payload entirely,
                // decaying the NaN to an infinity of the same sign — the
                // price of the paper's "just fill in zeroes" scheme. It
                // must never come back finite.
                assert!(!back.is_finite(), "NaN {bits:#010x} came back finite: {back}");
            } else if x.is_infinite() {
                assert_eq!(back.to_bits(), bits, "infinity not preserved");
            } else {
                // Truncation never grows magnitude and never flips sign.
                assert!(back.abs() <= x.abs(), "magnitude grew: {x} -> {back}");
                assert_eq!(back.is_sign_negative(), x.is_sign_negative(), "sign flip on {x}");
                if x != 0.0 && x.abs() >= f32::MIN_POSITIVE {
                    // Normal values: the documented relative bound.
                    let rel = ((x - back) / x).abs();
                    assert!(
                        rel <= MAX_RELATIVE_ERROR,
                        "x={x} back={back} rel={rel} exceeds {MAX_RELATIVE_ERROR}"
                    );
                }
            }
        }
    }

    #[test]
    fn property_idempotent() {
        // A value that already survived one round trip is a fixed point:
        // the second trip is bit-identical (the compressed lattice is
        // closed under truncation).
        let mut rng = crate::util::rng::Pcg32::new(0xfeed);
        for _ in 0..100_000 {
            let x = f32::from_bits(rng.next_u32());
            let once = roundtrip1(x);
            let twice = roundtrip1(once);
            assert_eq!(
                twice.to_bits(),
                once.to_bits(),
                "not idempotent: {x} -> {once} -> {twice}"
            );
        }
    }

    #[test]
    fn signed_zeros_preserved_bitwise() {
        assert_eq!(roundtrip1(0.0).to_bits(), 0.0f32.to_bits());
        assert_eq!(roundtrip1(-0.0).to_bits(), (-0.0f32).to_bits());
    }

    #[test]
    fn denormals_truncate_toward_zero() {
        // Subnormals keep their (zero) exponent; the surviving top-7
        // mantissa bits shrink toward zero, never flush to a wrong sign or
        // a larger magnitude. The relative bound does NOT apply here.
        let mind = f32::from_bits(1); // smallest positive subnormal
        assert_eq!(roundtrip1(mind), 0.0);
        assert!(roundtrip1(-mind).is_sign_negative());
        let bigd = f32::from_bits(0x007f_ffff); // largest subnormal
        let back = roundtrip1(bigd);
        assert!(back > 0.0 && back <= bigd);
        // A subnormal with payload entirely in the upper mantissa bits is
        // exact.
        let hi = f32::from_bits(0x007f_0000);
        assert_eq!(roundtrip1(hi).to_bits(), hi.to_bits());
    }

    #[test]
    fn quiet_nan_stays_nan() {
        assert!(roundtrip1(f32::NAN).is_nan());
    }

    #[test]
    fn truncation_not_rounding() {
        // The paper says truncate (cheaper than probabilistic rounding):
        // 1.0 + 2^-9 truncates back to 1.0.
        let x = 1.0f32 + 2f32.powi(-9);
        let t = Tensor::scalar_f32(x);
        let rt = bf16_to_f32(&f32_to_bf16(&t).unwrap()).unwrap();
        assert_eq!(rt.scalar_value_f32().unwrap(), 1.0);
    }
}
