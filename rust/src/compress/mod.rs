//! §5.5 lossy compression for cross-device/cross-machine tensor transfers:
//! "convert 32-bit floating point representations into a 16-bit floating
//! point representation (… a 32-bit IEEE 794 float format, but with 16
//! bits less precision in the mantissa), and then convert back … by just
//! filling in zeroes for the lost portion of the mantissa".
//!
//! That is precisely bf16-by-truncation: keep the upper 16 bits of the f32
//! (sign + 8 exponent + 7 mantissa bits), zero-fill on decode.

use crate::error::Result;
use crate::tensor::{Tensor, TensorData};

/// Truncate an f32 tensor to the bf16 wire format (upper 16 bits).
pub fn f32_to_bf16(t: &Tensor) -> Result<Tensor> {
    let v = t.as_f32()?;
    let out: Vec<u16> = v.iter().map(|&x| (x.to_bits() >> 16) as u16).collect();
    Tensor::new(t.shape().clone(), TensorData::BF16(out))
}

/// Expand a bf16 wire tensor back to f32 with zero-filled mantissa.
pub fn bf16_to_f32(t: &Tensor) -> Result<Tensor> {
    let v = t.as_bf16_raw()?;
    let out: Vec<f32> = v.iter().map(|&x| f32::from_bits((x as u32) << 16)).collect();
    Tensor::new(t.shape().clone(), TensorData::F32(out))
}

/// Worst-case relative error of the truncation: one ulp of a 7-bit
/// mantissa, i.e. 2^-7 (truncation loses up to a full ulp; rounding would
/// halve this — the paper explicitly chooses the cheaper truncation).
pub const MAX_RELATIVE_ERROR: f32 = 1.0 / 128.0;

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_error_bounded() {
        let vals: Vec<f32> = vec![
            0.0, 1.0, -1.0, 3.14159, -2.71828, 1e-20, 1e20, 123456.789, -0.000123,
        ];
        let t = Tensor::from_f32(vec![vals.len()], vals.clone()).unwrap();
        let rt = bf16_to_f32(&f32_to_bf16(&t).unwrap()).unwrap();
        for (&orig, &back) in vals.iter().zip(rt.as_f32().unwrap()) {
            if orig == 0.0 {
                assert_eq!(back, 0.0);
            } else {
                let rel = ((orig - back) / orig).abs();
                assert!(rel <= MAX_RELATIVE_ERROR, "orig={orig} back={back} rel={rel}");
            }
        }
    }

    #[test]
    fn halves_the_bytes() {
        let t = Tensor::from_f32(vec![100], vec![1.5; 100]).unwrap();
        let c = f32_to_bf16(&t).unwrap();
        assert_eq!(c.size_bytes(), t.size_bytes() / 2);
    }

    #[test]
    fn special_values_preserved() {
        let t = Tensor::from_f32(vec![3], vec![f32::INFINITY, f32::NEG_INFINITY, f32::NAN])
            .unwrap();
        let rt = bf16_to_f32(&f32_to_bf16(&t).unwrap()).unwrap();
        let v = rt.as_f32().unwrap();
        assert_eq!(v[0], f32::INFINITY);
        assert_eq!(v[1], f32::NEG_INFINITY);
        assert!(v[2].is_nan());
    }

    #[test]
    fn exact_for_small_integers() {
        // Integers up to 2^7 are exactly representable in 7 mantissa bits…
        for i in 0..=128 {
            let t = Tensor::scalar_f32(i as f32);
            let rt = bf16_to_f32(&f32_to_bf16(&t).unwrap()).unwrap();
            assert_eq!(rt.scalar_value_f32().unwrap(), i as f32);
        }
    }

    #[test]
    fn truncation_not_rounding() {
        // The paper says truncate (cheaper than probabilistic rounding):
        // 1.0 + 2^-9 truncates back to 1.0.
        let x = 1.0f32 + 2f32.powi(-9);
        let t = Tensor::scalar_f32(x);
        let rt = bf16_to_f32(&f32_to_bf16(&t).unwrap()).unwrap();
        assert_eq!(rt.scalar_value_f32().unwrap(), 1.0);
    }
}
