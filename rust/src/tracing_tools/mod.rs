//! EEG analog (§9.2): "collect and visualize very fine-grained information
//! about the exact ordering and performance characteristics of the
//! execution of TensorFlow graphs … reconstruct the execution of a
//! distributed training step with microsecond-level details."
//!
//! The executor begins/ends a span per kernel invocation; the distributed
//! layer adds spans for its own phases (replica pull/compute/push, the
//! parameter server's recv → barrier-wait → apply). Spans carry the node
//! name, op, device, thread, step id, and µs timestamps. Export is
//! chrome://tracing "trace event" JSON (the modern equivalent of the
//! paper's EEG viewer) plus a text summary of where time went.
//!
//! **Cross-process reconstruction.** Every collector in a process stamps
//! events against one process-wide monotonic epoch ([`process_now_us`]),
//! so events from different collectors in the same process share a
//! timeline directly. Across processes, each collector carries a
//! `process` label and ships its events as a [`TraceFragment`] (over
//! `MSG_TRACE_*` wire messages); the merging side pairs each fragment
//! with a clock offset estimated during the connection handshake and
//! [`merge_fragments`] shifts everything onto the merger's timeline —
//! one chrome://tracing JSON covering a whole distributed step.
//!
//! **Bounded memory.** A collector holds at most `cap` events; further
//! `record`s are counted in `dropped()` instead of growing without limit
//! (a long-lived server with `trace: true` must not leak).
//!
//! **[`StepStats`]** is the profile side of the same data: per-node
//! accumulated timings (plus the memory planner's per-step arena deltas)
//! in the exact shape [`crate::placement::CostModel`] consumes — the
//! bridge from "trace viewed by a human" to "trace fed back into
//! placement".

use crate::memory::MemoryReport;
use crate::util::json::Json;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex, OnceLock};
use std::time::Instant;

/// Default per-collector event cap (~26 MB of events at ~100 B each).
pub const DEFAULT_EVENT_CAP: usize = 262_144;

/// The process-wide trace epoch: every collector in this process stamps
/// events as µs since this instant, so events from different collectors
/// (the session's, the parameter server's, a replica driver's) are
/// directly comparable without per-collector re-basing.
fn process_epoch() -> Instant {
    static EPOCH: OnceLock<Instant> = OnceLock::new();
    *EPOCH.get_or_init(Instant::now)
}

/// µs since the process trace epoch — the timestamp every span uses, and
/// the value exchanged in clock-offset handshakes.
pub fn process_now_us() -> u64 {
    process_epoch().elapsed().as_micros() as u64
}

// Thread ids are allocated process-wide (not per collector): a pool
// thread that outlives many per-run collectors keeps one stable id, and
// two live collectors can never hand the same id to different threads.
fn thread_id() -> u64 {
    static NEXT_THREAD_ID: AtomicU64 = AtomicU64::new(1);
    thread_local! {
        static THREAD_ID: std::cell::Cell<u64> = const { std::cell::Cell::new(u64::MAX) };
    }
    THREAD_ID.with(|c| {
        if c.get() == u64::MAX {
            c.set(NEXT_THREAD_ID.fetch_add(1, Ordering::Relaxed));
        }
        c.get()
    })
}

/// One completed span.
#[derive(Debug, Clone, PartialEq)]
pub struct Event {
    pub name: String,
    pub op: String,
    pub device: String,
    pub thread: u64,
    pub start_us: u64,
    pub dur_us: u64,
    /// The distributed step this span belongs to (0 when untracked).
    pub step: u64,
    /// Total bytes of the span's output tensors (0 when unknown — phase
    /// and control spans, or spans ended without byte attribution).
    pub out_bytes: u64,
}

/// Collects events for one (or more) steps, on behalf of one process
/// role (`"local"`, `"replica:0"`, `"ps"`, `"worker:1"`, …).
pub struct TraceCollector {
    process: String,
    step_id: u64,
    cap: usize,
    events: Mutex<Vec<Event>>,
    dropped: AtomicU64,
}

impl TraceCollector {
    pub fn new() -> Arc<TraceCollector> {
        TraceCollector::for_step("local", 0)
    }

    /// A collector whose spans default to `step_id` and whose fragment
    /// carries `process` identity.
    pub fn for_step(process: &str, step_id: u64) -> Arc<TraceCollector> {
        TraceCollector::with_cap(process, step_id, DEFAULT_EVENT_CAP)
    }

    /// [`TraceCollector::for_step`] with an explicit event cap; events
    /// past the cap are dropped and counted, never stored.
    pub fn with_cap(process: &str, step_id: u64, cap: usize) -> Arc<TraceCollector> {
        Arc::new(TraceCollector {
            process: process.to_string(),
            step_id,
            cap,
            events: Mutex::new(Vec::new()),
            dropped: AtomicU64::new(0),
        })
    }

    pub fn process(&self) -> &str {
        &self.process
    }

    pub fn step_id(&self) -> u64 {
        self.step_id
    }

    /// Events rejected by the cap so far.
    pub fn dropped(&self) -> u64 {
        self.dropped.load(Ordering::Relaxed)
    }

    /// Begin a span tagged with the collector's default step; the
    /// returned guard records the event on `end()`.
    pub fn begin(self: &Arc<Self>, name: &str, op: &str, device: &str) -> Span {
        self.begin_step(name, op, device, self.step_id)
    }

    /// Begin a span with an explicit step id (long-lived collectors —
    /// the parameter server's — span many steps).
    pub fn begin_step(self: &Arc<Self>, name: &str, op: &str, device: &str, step: u64) -> Span {
        Span {
            collector: Arc::clone(self),
            name: name.to_string(),
            op: op.to_string(),
            device: device.to_string(),
            step,
            start: Instant::now(),
            start_us: process_now_us(),
        }
    }

    pub fn record(&self, ev: Event) {
        let mut evs = self.events.lock().unwrap();
        if evs.len() < self.cap {
            evs.push(ev);
        } else {
            self.dropped.fetch_add(1, Ordering::Relaxed);
            dropped_events_counter().inc();
        }
    }

    /// Append a batch of already-recorded events (e.g. a per-run child
    /// collector's), honoring the cap.
    pub fn absorb(&self, batch: Vec<Event>) {
        let mut evs = self.events.lock().unwrap();
        for ev in batch {
            if evs.len() < self.cap {
                evs.push(ev);
            } else {
                self.dropped.fetch_add(1, Ordering::Relaxed);
                dropped_events_counter().inc();
            }
        }
    }

    pub fn events(&self) -> Vec<Event> {
        self.events.lock().unwrap().clone()
    }

    /// Take all events, leaving the collector empty (the wire "pull a
    /// fragment" semantics: each event ships exactly once).
    pub fn drain(&self) -> Vec<Event> {
        std::mem::take(&mut *self.events.lock().unwrap())
    }

    /// Snapshot as a fragment (does not drain).
    pub fn fragment(&self) -> TraceFragment {
        TraceFragment {
            process: self.process.clone(),
            events: self.events(),
            dropped: self.dropped(),
        }
    }

    /// Drain into a fragment (the wire serving path).
    pub fn take_fragment(&self) -> TraceFragment {
        TraceFragment {
            process: self.process.clone(),
            events: self.drain(),
            dropped: self.dropped(),
        }
    }

    pub fn len(&self) -> usize {
        self.events.lock().unwrap().len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Render chrome://tracing JSON ("trace event format", array form).
    /// A capped collector leads with a metadata record announcing how
    /// many events were dropped, so the gap is visible in the viewer.
    pub fn to_chrome_trace(&self) -> String {
        let mut arr = Json::arr();
        let dropped = self.dropped();
        if dropped > 0 {
            arr.push(dropped_metadata(&self.process, dropped));
        }
        for ev in self.events.lock().unwrap().iter() {
            arr.push(chrome_event(ev, &ev.device));
        }
        arr.render()
    }

    /// Text summary: total µs per op, descending — the "appropriate detail
    /// level" overview of §9.2.
    pub fn summary(&self) -> String {
        use std::collections::HashMap;
        let mut per_op: HashMap<String, (u64, u64)> = HashMap::new();
        for ev in self.events.lock().unwrap().iter() {
            let e = per_op.entry(ev.op.clone()).or_default();
            e.0 += ev.dur_us;
            e.1 += 1;
        }
        let mut rows: Vec<(String, u64, u64)> =
            per_op.into_iter().map(|(op, (us, n))| (op, us, n)).collect();
        rows.sort_by(|a, b| b.1.cmp(&a.1));
        let mut out = String::from("op                              total_us      count\n");
        for (op, us, n) in rows {
            out.push_str(&format!("{op:<30} {us:>10} {n:>10}\n"));
        }
        out
    }

    pub fn now_us(&self) -> u64 {
        process_now_us()
    }
}

/// Process-wide count of events rejected at any collector's cap, in the
/// global metrics registry — truncation shows up in `/varz`, not just as
/// a mystery gap in the timeline.
fn dropped_events_counter() -> &'static Arc<crate::obs::Counter> {
    static COUNTER: OnceLock<Arc<crate::obs::Counter>> = OnceLock::new();
    COUNTER.get_or_init(|| crate::obs::global().counter("trace/dropped_events"))
}

/// chrome://tracing metadata record announcing `dropped` cap-rejected
/// events: the timeline is explicitly incomplete.
fn dropped_metadata(pid: &str, dropped: u64) -> Json {
    Json::obj()
        .set("name", "trace_dropped_events")
        .set("ph", "M")
        .set("pid", pid)
        .set("args", Json::obj().set("dropped", dropped))
}

fn chrome_event(ev: &Event, pid: &str) -> Json {
    Json::obj()
        .set("name", ev.name.clone())
        .set("cat", ev.op.clone())
        .set("ph", "X")
        .set("ts", ev.start_us)
        .set("dur", ev.dur_us.max(1))
        .set("pid", pid)
        .set("tid", ev.thread)
        .set("args", Json::obj().set("step", ev.step).set("device", ev.device.clone()))
}

/// Span guard (explicit `end()`, so async kernels can carry it into their
/// continuation).
pub struct Span {
    collector: Arc<TraceCollector>,
    name: String,
    op: String,
    device: String,
    step: u64,
    start: Instant,
    start_us: u64,
}

impl Span {
    pub fn end(self) {
        self.end_with_bytes(0);
    }

    /// End the span attributing `out_bytes` of output-tensor bytes to it
    /// (the executor's per-node memory attribution; feeds
    /// [`NodeStats::peak_bytes`]).
    pub fn end_with_bytes(self, out_bytes: u64) {
        let dur_us = self.start.elapsed().as_micros() as u64;
        let thread = thread_id();
        self.collector.record(Event {
            name: self.name,
            op: self.op,
            device: self.device,
            thread,
            start_us: self.start_us,
            dur_us,
            step: self.step,
            out_bytes,
        });
    }
}

// ---- cross-process merge ---------------------------------------------------

/// One process's share of a distributed step trace: what ships over
/// `MSG_TRACE_PULL` (codec in `distributed::proto`).
#[derive(Debug, Clone, PartialEq)]
pub struct TraceFragment {
    /// The emitting role: `"ps"`, `"replica:0"`, `"worker:1"`, …
    pub process: String,
    pub events: Vec<Event>,
    /// Events that collector rejected at its cap (visible so a merged
    /// trace can say "this timeline is incomplete").
    pub dropped: u64,
}

/// A set of fragments re-based onto one timeline (the merger's clock).
#[derive(Debug, Clone)]
pub struct MergedTrace {
    /// `(process, event)` pairs; `event.start_us` is already aligned.
    pub events: Vec<(String, Event)>,
    /// Total dropped-event count across all source fragments.
    pub dropped: u64,
}

/// Merge trace fragments from several processes onto one timeline. Each
/// fragment comes with the offset of *its* clock relative to the
/// merger's, in µs (positive = that process's clock reads ahead), as
/// estimated by the connection handshake; the merger's own fragment uses
/// offset 0. Timestamps are shifted by `-offset`, then the whole
/// timeline is normalized so the earliest event starts at 0.
pub fn merge_fragments(parts: Vec<(TraceFragment, i64)>) -> MergedTrace {
    let mut dropped = 0u64;
    let mut shifted: Vec<(String, Event, i64)> = Vec::new();
    for (frag, offset) in parts {
        dropped += frag.dropped;
        for ev in frag.events {
            let ts = ev.start_us as i64 - offset;
            shifted.push((frag.process.clone(), ev, ts));
        }
    }
    let base = shifted.iter().map(|(_, _, ts)| *ts).min().unwrap_or(0);
    let mut events: Vec<(String, Event)> = shifted
        .into_iter()
        .map(|(process, mut ev, ts)| {
            ev.start_us = (ts - base).max(0) as u64;
            (process, ev)
        })
        .collect();
    events.sort_by_key(|(_, ev)| ev.start_us);
    MergedTrace { events, dropped }
}

impl MergedTrace {
    /// chrome://tracing JSON with one `pid` lane per process; leads with
    /// a metadata record when any source fragment dropped events.
    pub fn to_chrome_trace(&self) -> String {
        let mut arr = Json::arr();
        if self.dropped > 0 {
            arr.push(dropped_metadata("merged", self.dropped));
        }
        for (process, ev) in &self.events {
            arr.push(chrome_event(ev, process));
        }
        arr.render()
    }

    /// Events belonging to `process` (prefix match, so `"replica"`
    /// matches every replica lane).
    pub fn events_of(&self, process_prefix: &str) -> Vec<&Event> {
        self.events
            .iter()
            .filter(|(p, _)| p.starts_with(process_prefix))
            .map(|(_, ev)| ev)
            .collect()
    }
}

// ---- StepStats -------------------------------------------------------------

/// Accumulated timings of one node across a step.
#[derive(Debug, Clone, PartialEq)]
pub struct NodeStats {
    pub name: String,
    pub op: String,
    pub device: String,
    pub total_us: u64,
    pub count: u64,
    /// Peak output-tensor bytes across the node's executions this step
    /// (0 for spans without byte attribution).
    pub peak_bytes: u64,
}

impl NodeStats {
    pub fn mean_us(&self) -> u64 {
        if self.count == 0 {
            0
        } else {
            self.total_us / self.count
        }
    }
}

/// Per-step profile: per-node accumulated timings plus the memory
/// planner's arena-counter deltas for the step. Produced by
/// `Session::run` when tracing; consumed by
/// [`crate::placement::CostModel::update_from_step_stats`] (ROADMAP
/// direction 5) and persistable via [`StepStats::to_json`] /
/// [`StepStats::from_json`].
#[derive(Debug, Clone, Default)]
pub struct StepStats {
    pub step_id: u64,
    /// Sorted by `total_us` descending.
    pub nodes: Vec<NodeStats>,
    /// One report per partition executor; `runtime` holds the *delta* of
    /// the arena counters across this step (approximate if other steps
    /// run concurrently — the counters are shared).
    pub memory: Vec<MemoryReport>,
}

impl StepStats {
    /// Aggregate raw span events (node executions within one step) into
    /// per-node totals. Control/diagnostic spans carry node names too, so
    /// everything the trace saw is accounted.
    pub fn from_events(step_id: u64, events: &[Event], memory: Vec<MemoryReport>) -> StepStats {
        use std::collections::HashMap;
        let mut per_node: HashMap<&str, NodeStats> = HashMap::new();
        for ev in events {
            let e = per_node.entry(ev.name.as_str()).or_insert_with(|| NodeStats {
                name: ev.name.clone(),
                op: ev.op.clone(),
                device: ev.device.clone(),
                total_us: 0,
                count: 0,
                peak_bytes: 0,
            });
            e.total_us += ev.dur_us;
            e.count += 1;
            e.peak_bytes = e.peak_bytes.max(ev.out_bytes);
        }
        let mut nodes: Vec<NodeStats> = per_node.into_values().collect();
        nodes.sort_by(|a, b| b.total_us.cmp(&a.total_us).then_with(|| a.name.cmp(&b.name)));
        StepStats { step_id, nodes, memory }
    }

    /// Total traced µs across all nodes.
    pub fn total_us(&self) -> u64 {
        self.nodes.iter().map(|n| n.total_us).sum()
    }

    pub fn node(&self, name: &str) -> Option<&NodeStats> {
        self.nodes.iter().find(|n| n.name == name)
    }

    pub fn to_json(&self) -> String {
        let mut nodes = Json::arr();
        for n in &self.nodes {
            nodes.push(
                Json::obj()
                    .set("name", n.name.clone())
                    .set("op", n.op.clone())
                    .set("device", n.device.clone())
                    .set("total_us", n.total_us)
                    .set("count", n.count)
                    .set("peak_bytes", n.peak_bytes),
            );
        }
        let mut memory = Json::arr();
        for m in &self.memory {
            memory.push(
                Json::obj()
                    .set("device", m.device.clone())
                    .set("arena_bytes", m.plan.arena_bytes)
                    .set("naive_bytes", m.plan.naive_bytes)
                    .set("num_slots", m.plan.num_slots)
                    .set("planned_static", m.plan.planned_static)
                    .set("planned_dynamic", m.plan.planned_dynamic)
                    .set("unplanned", m.plan.unplanned)
                    .set("forward_candidates", m.plan.forward_candidates)
                    .set("checkouts", m.runtime.checkouts)
                    .set("arenas_created", m.runtime.arenas_created)
                    .set("reuse_hits", m.runtime.reuse_hits)
                    .set("reuse_misses", m.runtime.reuse_misses)
                    .set("bytes_reused", m.runtime.bytes_reused)
                    .set("bytes_fresh", m.runtime.bytes_fresh)
                    .set("forwards_taken", m.runtime.forwards_taken)
                    .set("bytes_forwarded", m.runtime.bytes_forwarded)
                    .set("scratch_checkouts", m.runtime.scratch_checkouts)
                    .set("scratch_bytes_fresh", m.runtime.scratch_bytes_fresh)
                    .set("hw_planned_bytes", m.high_water.planned_bytes)
                    .set("hw_dynamic_bytes", m.high_water.dynamic_bytes)
                    .set("hw_scratch_bytes", m.high_water.scratch_bytes),
            );
        }
        Json::obj()
            .set("step_id", self.step_id)
            .set("nodes", nodes)
            .set("memory", memory)
            .render()
    }

    /// Parse a persisted [`StepStats::to_json`] dump back (profile files
    /// survive across processes / sessions for direction-5 replay).
    pub fn from_json(s: &str) -> Result<StepStats, crate::error::Status> {
        let bad = |m: String| crate::error::Status::invalid_argument(format!("StepStats: {m}"));
        let j = Json::parse(s).map_err(bad)?;
        let u = |v: Option<&Json>| v.and_then(Json::as_i64).unwrap_or(0).max(0) as u64;
        let mut out = StepStats { step_id: u(j.get("step_id")), ..Default::default() };
        for n in j.get("nodes").and_then(Json::as_array).unwrap_or(&[]) {
            out.nodes.push(NodeStats {
                name: n.get("name").and_then(Json::as_str).unwrap_or("").to_string(),
                op: n.get("op").and_then(Json::as_str).unwrap_or("").to_string(),
                device: n.get("device").and_then(Json::as_str).unwrap_or("").to_string(),
                total_us: u(n.get("total_us")),
                count: u(n.get("count")),
                peak_bytes: u(n.get("peak_bytes")),
            });
        }
        for m in j.get("memory").and_then(Json::as_array).unwrap_or(&[]) {
            let mut rep = MemoryReport {
                device: m.get("device").and_then(Json::as_str).unwrap_or("").to_string(),
                ..Default::default()
            };
            rep.plan.arena_bytes = u(m.get("arena_bytes")) as usize;
            rep.plan.naive_bytes = u(m.get("naive_bytes")) as usize;
            rep.plan.num_slots = u(m.get("num_slots")) as usize;
            rep.plan.planned_static = u(m.get("planned_static")) as usize;
            rep.plan.planned_dynamic = u(m.get("planned_dynamic")) as usize;
            rep.plan.unplanned = u(m.get("unplanned")) as usize;
            rep.plan.forward_candidates = u(m.get("forward_candidates")) as usize;
            rep.runtime.checkouts = u(m.get("checkouts"));
            rep.runtime.arenas_created = u(m.get("arenas_created"));
            rep.runtime.reuse_hits = u(m.get("reuse_hits"));
            rep.runtime.reuse_misses = u(m.get("reuse_misses"));
            rep.runtime.bytes_reused = u(m.get("bytes_reused"));
            rep.runtime.bytes_fresh = u(m.get("bytes_fresh"));
            rep.runtime.forwards_taken = u(m.get("forwards_taken"));
            rep.runtime.bytes_forwarded = u(m.get("bytes_forwarded"));
            rep.runtime.scratch_checkouts = u(m.get("scratch_checkouts"));
            rep.runtime.scratch_bytes_fresh = u(m.get("scratch_bytes_fresh"));
            rep.high_water.planned_bytes = u(m.get("hw_planned_bytes"));
            rep.high_water.dynamic_bytes = u(m.get("hw_dynamic_bytes"));
            rep.high_water.scratch_bytes = u(m.get("hw_scratch_bytes"));
            out.memory.push(rep);
        }
        Ok(out)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn spans_record_events() {
        let c = TraceCollector::new();
        let s = c.begin("MatMul_1", "MatMul", "/device:cpu:0");
        std::thread::sleep(std::time::Duration::from_millis(1));
        s.end();
        let evs = c.events();
        assert_eq!(evs.len(), 1);
        assert_eq!(evs[0].op, "MatMul");
        assert!(evs[0].dur_us >= 1000);
    }

    #[test]
    fn chrome_trace_is_json_array() {
        let c = TraceCollector::new();
        c.begin("a", "Add", "d0").end();
        c.begin("b", "Mul", "d1").end();
        let j = c.to_chrome_trace();
        assert!(j.starts_with('['));
        assert!(j.ends_with(']'));
        assert!(j.contains("\"ph\":\"X\""));
        assert!(j.contains("\"pid\":\"d1\""));
    }

    #[test]
    fn summary_aggregates_per_op() {
        let c = TraceCollector::new();
        c.begin("a1", "Add", "d").end();
        c.begin("a2", "Add", "d").end();
        c.begin("m", "MatMul", "d").end();
        let s = c.summary();
        assert!(s.contains("Add"));
        assert!(s.contains("MatMul"));
    }

    #[test]
    fn threads_get_distinct_ids() {
        let c = TraceCollector::new();
        let c2 = Arc::clone(&c);
        c.begin("main", "Op", "d").end();
        std::thread::spawn(move || {
            c2.begin("other", "Op", "d").end();
        })
        .join()
        .unwrap();
        let evs = c.events();
        assert_ne!(evs[0].thread, evs[1].thread);
    }

    #[test]
    fn cap_drops_and_counts() {
        let c = TraceCollector::with_cap("local", 0, 2);
        for i in 0..5 {
            c.begin(&format!("n{i}"), "Op", "d").end();
        }
        assert_eq!(c.len(), 2);
        assert_eq!(c.dropped(), 3);
        // Truncation is surfaced: the global registry counter moved and
        // the chrome trace leads with a metadata record.
        assert!(crate::obs::global().counter_value("trace/dropped_events").unwrap_or(0) >= 3);
        let j = c.to_chrome_trace();
        assert!(j.contains("\"trace_dropped_events\""), "{j}");
        assert!(j.contains("\"ph\":\"M\""), "{j}");
        assert!(j.contains("\"dropped\":3"), "{j}");
        // drain resets the buffer but keeps the dropped count (it is a
        // lifetime total, not a per-fragment one).
        let frag = c.take_fragment();
        assert_eq!(frag.events.len(), 2);
        assert_eq!(frag.dropped, 3);
        assert!(c.is_empty());
        c.begin("again", "Op", "d").end();
        assert_eq!(c.len(), 1);
    }

    #[test]
    fn spans_share_the_process_timeline() {
        // Two collectors created at different times must still agree on
        // timestamps (both use the process epoch, not their own).
        let a = TraceCollector::for_step("a", 1);
        a.begin("first", "Op", "d").end();
        std::thread::sleep(std::time::Duration::from_millis(2));
        let b = TraceCollector::for_step("b", 1);
        b.begin("second", "Op", "d").end();
        let ea = &a.events()[0];
        let eb = &b.events()[0];
        assert!(eb.start_us >= ea.start_us + 2000, "{} vs {}", eb.start_us, ea.start_us);
    }

    #[test]
    fn begin_step_overrides_collector_step() {
        let c = TraceCollector::for_step("ps", 7);
        c.begin("a", "Op", "d").end();
        c.begin_step("b", "Op", "d", 9).end();
        let evs = c.events();
        assert_eq!(evs[0].step, 7);
        assert_eq!(evs[1].step, 9);
    }

    #[test]
    fn merge_aligns_clocks_and_normalizes() {
        let ev = |start: u64, name: &str, step: u64| Event {
            name: name.to_string(),
            op: "Op".to_string(),
            device: "d".to_string(),
            thread: 1,
            start_us: start,
            dur_us: 10,
            step,
            out_bytes: 0,
        };
        let local = TraceFragment {
            process: "replica:0".into(),
            events: vec![ev(1000, "pull", 3)],
            dropped: 0,
        };
        // The remote clock reads 500µs ahead of ours; its event at
        // t=1510 remote happened at t=1010 local.
        let remote = TraceFragment {
            process: "ps".into(),
            events: vec![ev(1510, "apply", 3)],
            dropped: 2,
        };
        let merged = merge_fragments(vec![(local, 0), (remote, 500)]);
        assert_eq!(merged.dropped, 2);
        assert_eq!(merged.events.len(), 2);
        // Normalized: earliest at 0, remote re-based to +10µs.
        assert_eq!(merged.events[0].0, "replica:0");
        assert_eq!(merged.events[0].1.start_us, 0);
        assert_eq!(merged.events[1].0, "ps");
        assert_eq!(merged.events[1].1.start_us, 10);
        let j = merged.to_chrome_trace();
        let parsed = Json::parse(&j).unwrap();
        let arr = parsed.as_array().unwrap();
        // Dropped events from the ps fragment surface as a leading
        // metadata record, then the two duration events.
        assert_eq!(arr.len(), 3);
        assert_eq!(arr[0].get("ph").and_then(Json::as_str), Some("M"));
        assert_eq!(arr[0].get("args").unwrap().get("dropped").and_then(Json::as_i64), Some(2));
        assert_eq!(arr[2].get("pid").and_then(Json::as_str), Some("ps"));
        assert_eq!(arr[2].get("args").unwrap().get("step").and_then(Json::as_i64), Some(3));
        assert_eq!(merged.events_of("ps").len(), 1);
    }

    #[test]
    fn step_stats_aggregate_and_roundtrip() {
        let ev = |name: &str, dur: u64| Event {
            name: name.to_string(),
            op: "MatMul".to_string(),
            device: "/device:cpu:0".to_string(),
            thread: 1,
            start_us: 0,
            dur_us: dur,
            step: 4,
            out_bytes: dur * 100,
        };
        let ss = StepStats::from_events(4, &[ev("a", 10), ev("b", 50), ev("a", 30)], Vec::new());
        assert_eq!(ss.step_id, 4);
        assert_eq!(ss.nodes.len(), 2);
        assert_eq!(ss.nodes[0].name, "b"); // sorted by total desc
        assert_eq!(ss.node("a").unwrap().total_us, 40);
        assert_eq!(ss.node("a").unwrap().count, 2);
        assert_eq!(ss.node("a").unwrap().mean_us(), 20);
        assert_eq!(ss.node("a").unwrap().peak_bytes, 3000); // max, not sum
        assert_eq!(ss.node("b").unwrap().peak_bytes, 5000);
        assert_eq!(ss.total_us(), 90);
        let back = StepStats::from_json(&ss.to_json()).unwrap();
        assert_eq!(back.step_id, 4);
        assert_eq!(back.nodes, ss.nodes);
        assert!(StepStats::from_json("not json").is_err());
    }
}
