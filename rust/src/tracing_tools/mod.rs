//! EEG analog (§9.2): "collect and visualize very fine-grained information
//! about the exact ordering and performance characteristics of the
//! execution of TensorFlow graphs … reconstruct the execution of a
//! distributed training step with microsecond-level details."
//!
//! The executor begins/ends a span per kernel invocation; spans carry the
//! node name, op, device, thread, and µs timestamps. Export is
//! chrome://tracing "trace event" JSON (the modern equivalent of the
//! paper's EEG viewer) plus a text summary of where time went.

use crate::util::json::Json;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex};
use std::time::Instant;

/// One completed kernel span.
#[derive(Debug, Clone)]
pub struct Event {
    pub name: String,
    pub op: String,
    pub device: String,
    pub thread: u64,
    pub start_us: u64,
    pub dur_us: u64,
}

/// Collects events for one (or more) steps.
pub struct TraceCollector {
    epoch: Instant,
    events: Mutex<Vec<Event>>,
    next_thread_id: AtomicU64,
}

thread_local! {
    static THREAD_ID: std::cell::Cell<u64> = const { std::cell::Cell::new(u64::MAX) };
}

impl TraceCollector {
    pub fn new() -> Arc<TraceCollector> {
        Arc::new(TraceCollector {
            epoch: Instant::now(),
            events: Mutex::new(Vec::new()),
            next_thread_id: AtomicU64::new(1),
        })
    }

    fn thread_id(&self) -> u64 {
        THREAD_ID.with(|c| {
            if c.get() == u64::MAX {
                c.set(self.next_thread_id.fetch_add(1, Ordering::Relaxed));
            }
            c.get()
        })
    }

    /// Begin a span; returned guard records the event on `end()`.
    pub fn begin(self: &Arc<Self>, name: &str, op: &str, device: &str) -> Span {
        Span {
            collector: Arc::clone(self),
            name: name.to_string(),
            op: op.to_string(),
            device: device.to_string(),
            start: Instant::now(),
        }
    }

    pub fn record(&self, ev: Event) {
        self.events.lock().unwrap().push(ev);
    }

    pub fn events(&self) -> Vec<Event> {
        self.events.lock().unwrap().clone()
    }

    pub fn len(&self) -> usize {
        self.events.lock().unwrap().len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Render chrome://tracing JSON ("trace event format", array form).
    pub fn to_chrome_trace(&self) -> String {
        let mut arr = Json::arr();
        for ev in self.events.lock().unwrap().iter() {
            arr.push(
                Json::obj()
                    .set("name", ev.name.clone())
                    .set("cat", ev.op.clone())
                    .set("ph", "X")
                    .set("ts", ev.start_us)
                    .set("dur", ev.dur_us.max(1))
                    .set("pid", ev.device.clone())
                    .set("tid", ev.thread),
            );
        }
        arr.render()
    }

    /// Text summary: total µs per op, descending — the "appropriate detail
    /// level" overview of §9.2.
    pub fn summary(&self) -> String {
        use std::collections::HashMap;
        let mut per_op: HashMap<String, (u64, u64)> = HashMap::new();
        for ev in self.events.lock().unwrap().iter() {
            let e = per_op.entry(ev.op.clone()).or_default();
            e.0 += ev.dur_us;
            e.1 += 1;
        }
        let mut rows: Vec<(String, u64, u64)> =
            per_op.into_iter().map(|(op, (us, n))| (op, us, n)).collect();
        rows.sort_by(|a, b| b.1.cmp(&a.1));
        let mut out = String::from("op                              total_us      count\n");
        for (op, us, n) in rows {
            out.push_str(&format!("{op:<30} {us:>10} {n:>10}\n"));
        }
        out
    }

    pub fn now_us(&self) -> u64 {
        self.epoch.elapsed().as_micros() as u64
    }
}

/// Span guard (explicit `end()`, so async kernels can carry it into their
/// continuation).
pub struct Span {
    collector: Arc<TraceCollector>,
    name: String,
    op: String,
    device: String,
    start: Instant,
}

impl Span {
    pub fn end(self) {
        let start_us = self.start.duration_since(self.collector.epoch).as_micros() as u64;
        let dur_us = self.start.elapsed().as_micros() as u64;
        let thread = self.collector.thread_id();
        self.collector.record(Event {
            name: self.name,
            op: self.op,
            device: self.device,
            thread,
            start_us,
            dur_us,
        });
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn spans_record_events() {
        let c = TraceCollector::new();
        let s = c.begin("MatMul_1", "MatMul", "/device:cpu:0");
        std::thread::sleep(std::time::Duration::from_millis(1));
        s.end();
        let evs = c.events();
        assert_eq!(evs.len(), 1);
        assert_eq!(evs[0].op, "MatMul");
        assert!(evs[0].dur_us >= 1000);
    }

    #[test]
    fn chrome_trace_is_json_array() {
        let c = TraceCollector::new();
        c.begin("a", "Add", "d0").end();
        c.begin("b", "Mul", "d1").end();
        let j = c.to_chrome_trace();
        assert!(j.starts_with('['));
        assert!(j.ends_with(']'));
        assert!(j.contains("\"ph\":\"X\""));
        assert!(j.contains("\"pid\":\"d1\""));
    }

    #[test]
    fn summary_aggregates_per_op() {
        let c = TraceCollector::new();
        c.begin("a1", "Add", "d").end();
        c.begin("a2", "Add", "d").end();
        c.begin("m", "MatMul", "d").end();
        let s = c.summary();
        assert!(s.contains("Add"));
        assert!(s.contains("MatMul"));
    }

    #[test]
    fn threads_get_distinct_ids() {
        let c = TraceCollector::new();
        let c2 = Arc::clone(&c);
        c.begin("main", "Op", "d").end();
        std::thread::spawn(move || {
            c2.begin("other", "Op", "d").end();
        })
        .join()
        .unwrap();
        let evs = c.events();
        assert_ne!(evs[0].thread, evs[1].thread);
    }
}
