//! Intra-op parallelism bench: GFLOP/s on a large MatMul and steps/sec
//! on a fused matmul/bias/tanh stack, at 1 vs 4 intra-op threads, plus
//! the old serial ikj kernel as the no-regression baseline for the
//! 1-thread blocked kernel. Writes `BENCH_parallel.json` (path via
//! `BENCH_PARALLEL_JSON`; `scripts/bench.sh` points it at the repo
//! root).
//!
//! Acceptance bar (ISSUE 4): ≥ 2× matmul throughput at 4 intra-op
//! threads vs 1 — asserted only when the machine actually has ≥ 4 CPUs
//! (recorded as `assert_skipped` otherwise), and 1-thread blocked must
//! not regress below 0.7× the old serial kernel.

use rustflow::device::ComputePool;
use rustflow::kernels::matrix;
use rustflow::util::json::Json;
use rustflow::util::stats;
use rustflow::{GraphBuilder, Session, SessionOptions, Tensor};
use std::time::Duration;

const DIM: usize = 448; // large-matmul size (2·DIM³ ≈ 180 MFLOP/step)

fn filled(r: usize, c: usize, seed: u32) -> Tensor {
    let v: Vec<f32> = (0..r * c)
        .map(|i| {
            let h = (i as u32).wrapping_mul(2654435761).wrapping_add(seed);
            ((h % 1000) as f32) * 0.002 - 1.0
        })
        .collect();
    Tensor::from_f32(vec![r, c], v).unwrap()
}

/// The pre-refactor serial kernel body (ikj with zero-skip), kept here
/// verbatim as the regression baseline for the blocked 1-thread kernel.
fn naive_ikj(av: &[f32], bv: &[f32], m: usize, k: usize, n: usize, out: &mut [f32]) {
    for i in 0..m {
        for kk in 0..k {
            let aik = av[i * k + kk];
            if aik == 0.0 {
                continue;
            }
            let brow = &bv[kk * n..(kk + 1) * n];
            let crow = &mut out[i * n..(i + 1) * n];
            for j in 0..n {
                crow[j] += aik * brow[j];
            }
        }
    }
}

/// GFLOP/s of `f` where one call is a DIM³ multiply.
fn gflops(mut f: impl FnMut()) -> f64 {
    let s = stats::bench_for(1, Duration::from_secs(2), || f());
    let flops = 2.0 * (DIM as f64).powi(3);
    flops / s.mean.as_secs_f64() / 1e9
}

/// Steps/sec through a Session running a fused matmul/bias/tanh stack.
fn stack_steps_per_sec(intra: usize) -> (f64, Tensor) {
    let dim = 256usize;
    let depth = 6usize;
    let mut b = GraphBuilder::new();
    let x = b.placeholder("x", rustflow::DType::F32).unwrap();
    let mut h = x;
    for l in 0..depth as u32 {
        let w = b.constant(filled(dim, dim, 100 + l));
        let bias = b.constant(filled(1, dim, 200 + l));
        let mm = b.matmul(h, w);
        let s = b.add(mm, bias);
        h = b.tanh(s);
    }
    let fetch = format!("{}:0", b.graph.node(h.node).name);
    let sess = Session::new(
        b.into_graph(),
        SessionOptions { intra_op_threads: intra, ..Default::default() },
    );
    let feed = filled(dim, dim, 7);
    let run = || sess.run(&[("x", feed.clone())], &[&fetch], &[]).unwrap().remove(0);
    let out = run(); // warm: compile + fill arena pool
    let s = stats::bench_for(3, Duration::from_secs(2), || {
        run();
    });
    (1.0 / s.mean.as_secs_f64(), out)
}

fn main() {
    let cores = std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1);
    let a = filled(DIM, DIM, 1);
    let b = filled(DIM, DIM, 2);

    // Old serial kernel (the baseline), raw loop over raw slices.
    let (av, bv) = (a.as_f32().unwrap(), b.as_f32().unwrap());
    let mut scratch = vec![0f32; DIM * DIM];
    let naive = gflops(|| {
        scratch.iter_mut().for_each(|v| *v = 0.0);
        naive_ikj(av, bv, DIM, DIM, DIM, &mut scratch);
    });

    // New blocked kernel at 1 and 4 intra-op threads.
    let pool1 = ComputePool::serial();
    let pool4 = ComputePool::new(4, "bench-intra");
    let out1 = matrix::matmul_with_pool(&pool1, &a, &b, false, false).unwrap();
    let out4 = matrix::matmul_with_pool(&pool4, &a, &b, false, false).unwrap();
    assert_eq!(
        out1.as_f32().unwrap(),
        out4.as_f32().unwrap(),
        "1-thread and 4-thread matmul must be bit-identical"
    );
    let g1 = gflops(|| {
        matrix::matmul_with_pool(&pool1, &a, &b, false, false).unwrap();
    });
    let g4 = gflops(|| {
        matrix::matmul_with_pool(&pool4, &a, &b, false, false).unwrap();
    });
    let speedup = g4 / g1;
    let vs_naive = g1 / naive;
    println!(
        "parallel/matmul {DIM}x{DIM}x{DIM}: naive {naive:.2} GFLOP/s, blocked@1 {g1:.2}, \
         blocked@4 {g4:.2} ({speedup:.2}x vs 1t, {vs_naive:.2}x vs naive), {cores} cores"
    );

    // Whole-step throughput on the fused stack.
    let (sps1, stack_out1) = stack_steps_per_sec(1);
    let (sps4, stack_out4) = stack_steps_per_sec(4);
    assert_eq!(
        stack_out1.as_f32().unwrap(),
        stack_out4.as_f32().unwrap(),
        "stack results must be bit-identical across intra-op widths"
    );
    let stack_speedup = sps4 / sps1;
    println!(
        "parallel/stack 6x256 fused: {sps1:.1} steps/s @1t, {sps4:.1} steps/s @4t \
         ({stack_speedup:.2}x)"
    );

    // Acceptance bars.
    let assert_skipped = cores < 4;
    if assert_skipped {
        println!("note: {cores} cores < 4 — skipping the >=2x speedup assertion");
    } else {
        assert!(
            speedup >= 2.0,
            "4 intra-op threads must give >= 2x matmul throughput, got {speedup:.2}x"
        );
    }
    assert!(
        vs_naive >= 0.7,
        "blocked 1-thread kernel regressed vs the old serial kernel: {vs_naive:.2}x"
    );

    let out = Json::obj()
        .set("bench", "intra_op_parallelism")
        .set("matmul_dim", DIM as i64)
        .set("cores", cores as i64)
        .set("naive_serial_gflops", naive)
        .set("blocked_gflops_1t", g1)
        .set("blocked_gflops_4t", g4)
        .set("matmul_speedup_4t_vs_1t", speedup)
        .set("blocked_1t_vs_naive", vs_naive)
        .set("stack_steps_per_sec_1t", sps1)
        .set("stack_steps_per_sec_4t", sps4)
        .set("stack_speedup_4t_vs_1t", stack_speedup)
        .set("assert_skipped", assert_skipped);
    let path = std::env::var("BENCH_PARALLEL_JSON")
        .unwrap_or_else(|_| "BENCH_parallel.json".to_string());
    std::fs::write(&path, out.render() + "\n").expect("write bench json");
    println!("wrote {path}");
}
