//! Kernel-throughput bench: the packed-SIMD GEMM vs the previous
//! blocked-parallel kernel (kept verbatim below) and the old serial ikj
//! loop, GFLOP/s at 448³; the im2col Conv2D vs the direct serial
//! convolution, steps/sec; and whole-step throughput on a fused
//! matmul/bias/tanh stack at 1 vs 4 intra-op threads. Writes
//! `BENCH_parallel.json` (path via `BENCH_PARALLEL_JSON`;
//! `scripts/bench.sh` points it at the repo root).
//!
//! Acceptance bars (ISSUE 9): packed ≥ 2× the blocked kernel and
//! im2col Conv2D ≥ 3× the direct loop, both at 4 intra-op threads —
//! asserted only when the machine actually has ≥ 4 CPUs (recorded as
//! `assert_skipped` otherwise). Packed at 1 thread must not regress
//! below 0.7× the old serial kernel.
//!
//! `BENCH_SMOKE=1` shrinks the timing windows to a CI-sized smoke run:
//! every bit-identity cross-check still executes, the wall-clock
//! thresholds are skipped (shared-runner timings are noise).

use rustflow::device::ComputePool;
use rustflow::kernels::matrix;
use rustflow::kernels::nn::{self, Padding};
use rustflow::util::json::Json;
use rustflow::util::stats;
use rustflow::{GraphBuilder, Session, SessionOptions, Tensor};
use std::time::Duration;

const DIM: usize = 448; // large-matmul size (2·DIM³ ≈ 180 MFLOP/step)

fn filled(r: usize, c: usize, seed: u32) -> Tensor {
    let v: Vec<f32> = (0..r * c)
        .map(|i| {
            let h = (i as u32).wrapping_mul(2654435761).wrapping_add(seed);
            ((h % 1000) as f32) * 0.002 - 1.0
        })
        .collect();
    Tensor::from_f32(vec![r, c], v).unwrap()
}

/// NHWC/filter fill that can never produce an exact 0.0, so the serial
/// convolution's zero-input skip takes no branch the im2col form lacks.
fn filled_nz(dims: Vec<usize>, seed: u32) -> Tensor {
    let n: usize = dims.iter().product();
    let v: Vec<f32> = (0..n)
        .map(|i| {
            let h = (i as u32).wrapping_mul(2654435761).wrapping_add(seed);
            ((h % 1000) as f32) * 0.002 - 1.0005
        })
        .collect();
    Tensor::from_f32(dims, v).unwrap()
}

/// The pre-refactor serial kernel body (ikj with zero-skip), kept here
/// verbatim as the deep no-regression baseline.
fn naive_ikj(av: &[f32], bv: &[f32], m: usize, k: usize, n: usize, out: &mut [f32]) {
    for i in 0..m {
        for kk in 0..k {
            let aik = av[i * k + kk];
            if aik == 0.0 {
                continue;
            }
            let brow = &bv[kk * n..(kk + 1) * n];
            let crow = &mut out[i * n..(i + 1) * n];
            for j in 0..n {
                crow[j] += aik * brow[j];
            }
        }
    }
}

/// The pre-packing blocked-parallel kernel (the kernel this PR
/// replaced), kept verbatim as the headline comparison baseline: row
/// panels over the pool, KC×NC cache blocking, scalar inner loop.
fn blocked_parallel(
    pool: &ComputePool,
    av: &[f32],
    bv: &[f32],
    m: usize,
    k: usize,
    n: usize,
    out: &mut [f32],
) {
    const KC: usize = 128;
    const NC: usize = 512;
    let row_cost = 2 * k * n;
    pool.parallel_for_mut(m, row_cost, out, |rows, c| {
        let r0 = rows.start;
        for kb in (0..k).step_by(KC) {
            let kend = (kb + KC).min(k);
            for jb in (0..n).step_by(NC) {
                let jend = (jb + NC).min(n);
                for i in rows.clone() {
                    let crow = &mut c[(i - r0) * n + jb..(i - r0) * n + jend];
                    for kk in kb..kend {
                        let aik = av[i * k + kk];
                        if aik == 0.0 {
                            continue;
                        }
                        let brow = &bv[kk * n + jb..kk * n + jend];
                        for (cj, &bj) in crow.iter_mut().zip(brow) {
                            *cj += aik * bj;
                        }
                    }
                }
            }
        }
    });
}

/// GFLOP/s of `f`, where one call performs `flops` floating-point ops.
fn gflops(flops: f64, window: Duration, mut f: impl FnMut()) -> f64 {
    let s = stats::bench_for(1, window, || f());
    flops / s.mean.as_secs_f64() / 1e9
}

/// Steps/sec through a Session running a fused matmul/bias/tanh stack.
fn stack_steps_per_sec(intra: usize, window: Duration) -> (f64, Tensor) {
    let dim = 256usize;
    let depth = 6usize;
    let mut b = GraphBuilder::new();
    let x = b.placeholder("x", rustflow::DType::F32).unwrap();
    let mut h = x;
    for l in 0..depth as u32 {
        let w = b.constant(filled(dim, dim, 100 + l));
        let bias = b.constant(filled(1, dim, 200 + l));
        let mm = b.matmul(h, w);
        let s = b.add(mm, bias);
        h = b.tanh(s);
    }
    let fetch = format!("{}:0", b.graph.node(h.node).name);
    let sess = Session::new(
        b.into_graph(),
        SessionOptions { intra_op_threads: intra, ..Default::default() },
    );
    let feed = filled(dim, dim, 7);
    let run = || sess.run(&[("x", feed.clone())], &[&fetch], &[]).unwrap().remove(0);
    let out = run(); // warm: compile + fill arena pool
    let s = stats::bench_for(3, window, || {
        run();
    });
    (1.0 / s.mean.as_secs_f64(), out)
}

fn main() {
    let smoke = std::env::var("BENCH_SMOKE").is_ok();
    let window = if smoke { Duration::from_millis(150) } else { Duration::from_secs(2) };
    let cores = std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1);
    let a = filled(DIM, DIM, 1);
    let b = filled(DIM, DIM, 2);
    let mm_flops = 2.0 * (DIM as f64).powi(3);

    // Baselines: the old serial loop and the old blocked-parallel kernel.
    let (av, bv) = (a.as_f32().unwrap(), b.as_f32().unwrap());
    let mut scratch = vec![0f32; DIM * DIM];
    let naive = gflops(mm_flops, window, || {
        scratch.iter_mut().for_each(|v| *v = 0.0);
        naive_ikj(av, bv, DIM, DIM, DIM, &mut scratch);
    });
    let pool1 = ComputePool::serial();
    let pool4 = ComputePool::new(4, "bench-intra");
    let blocked4 = gflops(mm_flops, window, || {
        scratch.iter_mut().for_each(|v| *v = 0.0);
        blocked_parallel(&pool4, av, bv, DIM, DIM, DIM, &mut scratch);
    });

    // The packed GEMM at 1 and 4 intra-op threads, bit-identity checked.
    let out1 = matrix::matmul_with_pool(&pool1, &a, &b, false, false).unwrap();
    let out4 = matrix::matmul_with_pool(&pool4, &a, &b, false, false).unwrap();
    assert_eq!(
        out1.as_f32().unwrap(),
        out4.as_f32().unwrap(),
        "1-thread and 4-thread packed matmul must be bit-identical"
    );
    let g1 = gflops(mm_flops, window, || {
        matrix::matmul_with_pool(&pool1, &a, &b, false, false).unwrap();
    });
    let g4 = gflops(mm_flops, window, || {
        matrix::matmul_with_pool(&pool4, &a, &b, false, false).unwrap();
    });
    let speedup = g4 / g1;
    let vs_blocked = g4 / blocked4;
    let vs_naive = g1 / naive;
    println!(
        "parallel/matmul {DIM}x{DIM}x{DIM}: naive {naive:.2} GFLOP/s, blocked@4 {blocked4:.2}, \
         packed@1 {g1:.2}, packed@4 {g4:.2} ({speedup:.2}x vs 1t, {vs_blocked:.2}x vs blocked@4, \
         {vs_naive:.2}x vs naive@1), {cores} cores"
    );

    // im2col Conv2D vs the direct serial convolution (zero-free fills so
    // the direct form's zero-skip changes nothing; bytes must agree).
    let cx = filled_nz(vec![4, 32, 32, 8], 31);
    let cf = filled_nz(vec![3, 3, 8, 16], 32);
    let conv_ref = nn::conv2d(&cx, &cf, 1, Padding::Same).unwrap();
    let conv1 = nn::conv2d_with(&pool1, &cx, &cf, 1, Padding::Same).unwrap();
    let conv4 = nn::conv2d_with(&pool4, &cx, &cf, 1, Padding::Same).unwrap();
    assert_eq!(
        conv_ref.as_f32().unwrap(),
        conv1.as_f32().unwrap(),
        "im2col conv must match the direct serial convolution bitwise"
    );
    assert_eq!(
        conv1.as_f32().unwrap(),
        conv4.as_f32().unwrap(),
        "1-thread and 4-thread im2col conv must be bit-identical"
    );
    let conv_naive_sps = {
        let s = stats::bench_for(1, window, || {
            nn::conv2d(&cx, &cf, 1, Padding::Same).unwrap();
        });
        1.0 / s.mean.as_secs_f64()
    };
    let conv_packed_sps = {
        let s = stats::bench_for(1, window, || {
            nn::conv2d_with(&pool4, &cx, &cf, 1, Padding::Same).unwrap();
        });
        1.0 / s.mean.as_secs_f64()
    };
    let conv_speedup = conv_packed_sps / conv_naive_sps;
    println!(
        "parallel/conv2d 4x32x32x8 * 3x3x8x16: direct {conv_naive_sps:.1} steps/s, \
         im2col@4 {conv_packed_sps:.1} steps/s ({conv_speedup:.2}x)"
    );

    // Whole-step throughput on the fused stack.
    let (sps1, stack_out1) = stack_steps_per_sec(1, window);
    let (sps4, stack_out4) = stack_steps_per_sec(4, window);
    assert_eq!(
        stack_out1.as_f32().unwrap(),
        stack_out4.as_f32().unwrap(),
        "stack results must be bit-identical across intra-op widths"
    );
    let stack_speedup = sps4 / sps1;
    println!(
        "parallel/stack 6x256 fused: {sps1:.1} steps/s @1t, {sps4:.1} steps/s @4t \
         ({stack_speedup:.2}x)"
    );

    // Acceptance bars. Timing thresholds need real cores and a real
    // window; the bit-identity cross-checks above always ran.
    let assert_skipped = smoke || cores < 4;
    if assert_skipped {
        println!(
            "note: skipping throughput assertions (smoke={smoke}, {cores} cores) — \
             bit-identity checks all passed"
        );
    } else {
        assert!(
            vs_blocked >= 2.0,
            "packed GEMM must give >= 2x the blocked kernel at 4 threads, got {vs_blocked:.2}x"
        );
        assert!(
            conv_speedup >= 3.0,
            "im2col conv must give >= 3x the direct loop, got {conv_speedup:.2}x"
        );
        assert!(
            vs_naive >= 0.7,
            "packed 1-thread kernel regressed vs the old serial kernel: {vs_naive:.2}x"
        );
    }

    let out = Json::obj()
        .set("bench", "intra_op_parallelism")
        .set("matmul_dim", DIM as i64)
        .set("cores", cores as i64)
        .set("naive_serial_gflops", naive)
        .set("blocked_gflops_4t", blocked4)
        .set("packed_gflops_1t", g1)
        .set("packed_gflops_4t", g4)
        .set("matmul_speedup_4t_vs_1t", speedup)
        .set("packed_4t_vs_blocked_4t", vs_blocked)
        .set("packed_1t_vs_naive", vs_naive)
        .set("conv_direct_steps_per_sec", conv_naive_sps)
        .set("conv_packed_steps_per_sec_4t", conv_packed_sps)
        .set("conv_speedup_vs_direct", conv_speedup)
        .set("stack_steps_per_sec_1t", sps1)
        .set("stack_steps_per_sec_4t", sps4)
        .set("stack_speedup_4t_vs_1t", stack_speedup)
        .set("assert_skipped", assert_skipped);
    let path = std::env::var("BENCH_PARALLEL_JSON")
        .unwrap_or_else(|_| "BENCH_parallel.json".to_string());
    std::fs::write(&path, out.render() + "\n").expect("write bench json");
    println!("wrote {path}");
}
