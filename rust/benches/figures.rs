//! Regenerates every paper table/figure artifact (DESIGN.md §4 experiment
//! index). `cargo bench --bench figures` prints one section per artifact;
//! EXPERIMENTS.md records the measured outputs.

use rustflow::graph::AttrValue;
use rustflow::optim::Optimizer;
use rustflow::partition::PartitionOptions;
use rustflow::placement::CostModel;
use rustflow::util::rng::Pcg32;
use rustflow::util::stats;
use rustflow::{data, models, replicate, DType, GraphBuilder, Session, SessionOptions, Tensor};
use std::sync::Arc;
use std::time::Instant;

fn main() {
    println!("================ RustFlow paper-artifact benchmarks ================");
    table1_op_coverage();
    fig2_graph_dump();
    fig4_send_recv_canonicalization();
    fig5_gradients();
    fig6_partial_execution();
    fig7_data_parallel();
    fig8_model_parallel();
    fig9_concurrent_steps();
    sec5_cse();
    sec5_recv_scheduling();
    sec5_lossy_compression();
    sec6_inception_analog_vs_distbelief();
    sec46_queue_prefetch();
    sec92_eeg_trace();
}

// ---- Table 1 ---------------------------------------------------------------
fn table1_op_coverage() {
    println!("\n--- Table 1: operation categories (E2) ---");
    let ops = rustflow::ops::all_ops();
    let mut counts: std::collections::BTreeMap<String, usize> = Default::default();
    for (_, cat) in &ops {
        *counts.entry(format!("{cat:?}")).or_default() += 1;
    }
    for (cat, n) in counts {
        println!("{cat:<16} {n} ops");
    }
    for required in [
        "Add", "Sub", "Mul", "Div", "Exp", "Log", "Greater", "Less", "Equal", "Concat", "Slice",
        "Split", "Const", "Rank", "Shape", "Shuffle", "MatMul", "MatrixInverse",
        "MatrixDeterminant", "Variable", "Assign", "AssignAdd", "SoftMax", "Sigmoid", "ReLU",
        "Convolution2D", "MaxPool", "Save", "Restore", "Enqueue", "Dequeue", "MutexAcquire",
        "MutexRelease", "Merge", "Switch", "Enter", "Exit", "NextIteration",
    ] {
        assert!(rustflow::ops::is_registered(required), "Table-1 op {required} missing");
    }
    println!("all Table-1 example ops registered ✓");
}

// ---- Figure 2 ---------------------------------------------------------------
fn fig2_graph_dump() {
    println!("\n--- Figures 1/2: example program graph (E1) ---");
    let mut b = GraphBuilder::new();
    let bias = b.variable("b", Tensor::zeros(DType::F32, vec![100, 1]).unwrap()).unwrap();
    let w = b.variable_uniform("W", vec![100, 784], -1.0, 1.0, 0).unwrap();
    let x = b.placeholder("x", DType::F32).unwrap();
    let wx = b.matmul(w, x);
    let add = b.add(wx, bias);
    let _relu = b.relu(add);
    let dump = b.graph.dump();
    let interesting: Vec<&str> = dump
        .lines()
        .filter(|l| l.contains("MatMul") || l.contains("Add") || l.contains("ReLU"))
        .collect();
    for line in interesting {
        println!("{line}");
    }
}

// ---- Figure 4 ---------------------------------------------------------------
fn fig4_send_recv_canonicalization() {
    println!("\n--- Figure 4: Send/Recv insertion + canonicalization (E4) ---");
    // The figure's shape: x on device A; consumers b, c on device B.
    let build = || {
        let mut b = GraphBuilder::new();
        let x = b.with_device("/device:cpu:0", |b| {
            b.constant(Tensor::fill_f32(vec![256, 256], 0.1))
        });
        let y = b.with_device("/device:cpu:1", |b| b.relu(x));
        let z = b.with_device("/device:cpu:1", |b| b.mul(x, y));
        let w = b.with_device("/device:cpu:1", |b| b.add(x, z));
        let _ = w;
        let devices = rustflow::device::DeviceSet::local(2, 1);
        rustflow::placement::place(&mut b.graph, &devices, &CostModel::new()).unwrap();
        b.graph
    };
    for (label, canonicalize) in [("canonicalized (paper)", true), ("naive (1 recv/user)", false)] {
        let g = build();
        let opts = PartitionOptions { canonicalize, ..Default::default() };
        let (_, stats) = rustflow::partition::partition(&g, &opts, "").unwrap();
        let bytes = stats.transfers * 256 * 256 * 4;
        println!(
            "{label:<24} transfers={} sends={} recvs={} bytes_on_wire={}",
            stats.transfers, stats.send_nodes, stats.recv_nodes, bytes
        );
    }
}

// ---- Figure 5 ---------------------------------------------------------------
fn fig5_gradients() {
    println!("\n--- Figure 5: gradient graph extension (E5) ---");
    let mut b = GraphBuilder::new();
    let w = b.variable_uniform("W", vec![10, 10], -1.0, 1.0, 1).unwrap();
    let x = b.constant(Tensor::fill_f32(vec![10, 1], 0.5));
    let bias = b.variable("b", Tensor::zeros(DType::F32, vec![10, 1]).unwrap()).unwrap();
    let wx = b.matmul(w, x);
    let pre = b.add(wx, bias);
    let relu = b.relu(pre);
    let c = b.reduce_sum(relu, None);
    let before = b.graph.len();
    let grads = rustflow::autodiff::gradients(&mut b, c, &[bias, w, x]).unwrap();
    println!(
        "forward nodes: {before}; after tf.gradients(C,[b,W,x]): {} (+{} gradient nodes)",
        b.graph.len(),
        b.graph.len() - before
    );
    println!(
        "[db, dW, dx] = {:?}",
        grads
            .iter()
            .map(|g| g.map(|e| b.graph.node(e.node).op.clone()))
            .collect::<Vec<_>>()
    );
    assert!(grads.iter().all(|g| g.is_some()));
}

// ---- Figure 6 ---------------------------------------------------------------
fn fig6_partial_execution() {
    println!("\n--- Figure 6: partial execution / pruning (E6) ---");
    let mut b = GraphBuilder::new();
    let a = b.placeholder("a", DType::F32).unwrap();
    let bb = b.op1("Neg", "b", vec![a], vec![]).unwrap();
    let c = b.op1("Neg", "c", vec![bb], vec![]).unwrap();
    let _f = b.op1("Square", "f", vec![c], vec![]).unwrap();
    let d = b.op1("Neg", "d", vec![bb], vec![]).unwrap();
    let _e = b.op1("Neg", "e", vec![d], vec![]).unwrap();
    let full = b.graph.len();
    let (pruned, _, _) =
        rustflow::session::prune_for_run(&b.graph, &["b"], &["f:0"], &[]).unwrap();
    println!(
        "Run(inputs={{b}}, outputs={{f:0}}): full graph {full} nodes -> executed subgraph {} nodes",
        pruned.len()
    );
    println!("d executed: {}; e executed: {}", pruned.find("d").is_some(), pruned.find("e").is_some());
    assert!(pruned.find("d").is_none() && pruned.find("e").is_none());
}

// ---- Figure 7 ---------------------------------------------------------------
fn fig7_data_parallel() {
    println!("\n--- Figure 7: sync vs async data parallelism (E7) ---");
    // Towers sized so per-tower compute dominates dispatch overhead (the
    // regime Fig 7 targets).
    let (dim, classes, batch, steps) = (64usize, 10usize, 128usize, 30usize);
    println!("{:<8} {:>9} {:>12} {:>14} {:>12}", "mode", "replicas", "updates/s", "examples/s", "final loss");
    for &replicas in &[1usize, 2, 4] {
        for mode in ["sync", "async"] {
            let mut b = GraphBuilder::new();
            let vars = b.with_device("/device:cpu:0", |b| {
                let x = b.constant(Tensor::zeros(DType::F32, vec![1, dim]).unwrap());
                let (_, vars) = models::mlp(b, x, &[dim, 256, classes], 11).unwrap();
                vars
            });
            let examples = data::synthetic_classification(replicas * batch, dim, classes, 0.3, 5);
            let losses = replicate::build_towers(&mut b, replicas, |i| format!("/device:cpu:{i}"), |b, i| {
                let shard = &examples[i * batch..(i + 1) * batch];
                let (f, l) = data::batch_tensors(shard)?;
                let x = b.constant(f);
                let y = b.constant(data::one_hot(l.as_i32()?, classes));
                let mut h = x;
                for li in 0..vars.len() / 2 {
                    let mm = b.matmul(h, vars[2 * li]);
                    let pre = b.bias_add(mm, vars[2 * li + 1]);
                    h = if li + 1 < vars.len() / 2 { b.relu(pre) } else { pre };
                }
                models::xent_loss(b, h, y)
            })
            .unwrap();
            let inits: Vec<String> =
                b.init_ops.iter().map(|&i| b.graph.node(i).name.clone()).collect();
            let opt = Optimizer::sgd(0.05);
            let lname = format!("{}:0", b.graph.node(losses[0].node).name);
            let (elapsed, updates, final_loss) = match mode {
                "sync" => {
                    let train = replicate::sync_data_parallel(&mut b, &vars, &losses, &opt).unwrap();
                    let tname = b.graph.node(train).name.clone();
                    let sess = Session::new(
                        b.into_graph(),
                        SessionOptions { devices: replicas, ..Default::default() },
                    );
                    sess.run_targets(&inits.iter().map(|s| s.as_str()).collect::<Vec<_>>()).unwrap();
                    sess.run_targets(&[&tname]).unwrap(); // warmup/compile
                    let t0 = Instant::now();
                    for _ in 0..steps {
                        sess.run_targets(&[&tname]).unwrap();
                    }
                    let dt = t0.elapsed();
                    let l = sess.run(&[], &[&lname], &[]).unwrap()[0].scalar_value_f32().unwrap();
                    (dt, steps, l)
                }
                _ => {
                    let trains = replicate::async_data_parallel(&mut b, &vars, &losses, &opt).unwrap();
                    let tnames: Vec<String> =
                        trains.iter().map(|&t| b.graph.node(t).name.clone()).collect();
                    let sess = Arc::new(Session::new(
                        b.into_graph(),
                        SessionOptions { devices: replicas, ..Default::default() },
                    ));
                    sess.run_targets(&inits.iter().map(|s| s.as_str()).collect::<Vec<_>>()).unwrap();
                    for t in &tnames {
                        sess.run_targets(&[t]).unwrap();
                    }
                    let t0 = Instant::now();
                    std::thread::scope(|scope| {
                        for name in &tnames {
                            let sess = Arc::clone(&sess);
                            scope.spawn(move || {
                                for _ in 0..steps {
                                    sess.run_targets(&[name]).unwrap();
                                }
                            });
                        }
                    });
                    let dt = t0.elapsed();
                    let l = sess.run(&[], &[&lname], &[]).unwrap()[0].scalar_value_f32().unwrap();
                    (dt, steps * replicas, l)
                }
            };
            // A sync update consumes replicas×batch examples ("behave
            // exactly as if we were running … batch size of 1000"); an
            // async update consumes one tower's batch.
            let examples_per_update = if mode == "sync" { replicas * batch } else { batch };
            println!(
                "{mode:<8} {replicas:>9} {:>12.1} {:>14.0} {:>12.4}",
                updates as f64 / elapsed.as_secs_f64(),
                (updates * examples_per_update) as f64 / elapsed.as_secs_f64(),
                final_loss
            );
        }
    }
}

// ---- Figure 8 ---------------------------------------------------------------
fn fig8_model_parallel() {
    println!("\n--- Figure 8: model-parallel LSTM (E8) ---");
    let (layers, seq, batch, input_dim, hidden) = (3usize, 12usize, 8usize, 32usize, 128usize);
    for (label, devices, pin) in [("single-device", 1usize, false), ("model-parallel", layers, true)] {
        let mut b = GraphBuilder::new();
        let mut rng = Pcg32::new(3);
        let xs: Vec<_> = (0..seq)
            .map(|_| {
                b.constant(
                    Tensor::from_f32(
                        vec![batch, input_dim],
                        (0..batch * input_dim).map(|_| rng.normal() * 0.3).collect(),
                    )
                    .unwrap(),
                )
            })
            .collect();
        let device_fn = |l: usize| format!("/device:cpu:{l}");
        let (top, _) = models::stacked_lstm(
            &mut b, &xs, batch, input_dim, hidden, layers,
            if pin { Some(&device_fn) } else { None }, 9,
        )
        .unwrap();
        let out = b.reduce_mean(top, None);
        let oname = format!("{}:0", b.graph.node(out.node).name);
        let inits: Vec<String> = b.init_ops.iter().map(|&i| b.graph.node(i).name.clone()).collect();
        let sess = Session::new(
            b.into_graph(),
            SessionOptions { devices, threads_per_device: 2, ..Default::default() },
        );
        sess.run_targets(&inits.iter().map(|s| s.as_str()).collect::<Vec<_>>()).unwrap();
        let s = stats::bench(2, 15, || {
            sess.run(&[], &[&oname], &[]).unwrap();
        });
        let (_, xstats) = sess.step_stats(&[], &[&oname], &[]).unwrap();
        println!(
            "{label:<16} mean step {:?}  ({:.1} steps/s, {} cross-device transfers)",
            s.mean,
            1.0 / s.mean.as_secs_f64(),
            xstats.transfers
        );
    }
}

// ---- Figure 9 ---------------------------------------------------------------
fn fig9_concurrent_steps() {
    println!("\n--- Figure 9: concurrent steps pipelining (E9) ---");
    // One training subgraph; N client threads keep steps in flight
    // (asynchronous update semantics, §7).
    let (dim, classes) = (64usize, 10usize);
    println!("{:>18} {:>12}", "concurrent steps", "steps/s");
    for &concurrent in &[1usize, 2, 4, 8] {
        let mut b = GraphBuilder::new();
        let examples = data::synthetic_classification(64, dim, classes, 0.3, 7);
        let (f, l) = data::batch_tensors(&examples).unwrap();
        let x = b.constant(f);
        let y = b.constant(data::one_hot(l.as_i32().unwrap(), classes));
        let (logits, vars) = models::mlp(&mut b, x, &[dim, 128, classes], 3).unwrap();
        let loss = models::xent_loss(&mut b, logits, y).unwrap();
        let train = Optimizer::sgd(0.01).minimize(&mut b, loss, &vars).unwrap();
        let tname = b.graph.node(train).name.clone();
        let inits: Vec<String> = b.init_ops.iter().map(|&i| b.graph.node(i).name.clone()).collect();
        let sess = Arc::new(Session::new(
            b.into_graph(),
            SessionOptions { devices: 1, threads_per_device: 4, ..Default::default() },
        ));
        sess.run_targets(&inits.iter().map(|s| s.as_str()).collect::<Vec<_>>()).unwrap();
        sess.run_targets(&[&tname]).unwrap();
        let per_thread = 40usize;
        let t0 = Instant::now();
        std::thread::scope(|scope| {
            for _ in 0..concurrent {
                let sess = Arc::clone(&sess);
                let tname = tname.clone();
                scope.spawn(move || {
                    for _ in 0..per_thread {
                        sess.run_targets(&[&tname]).unwrap();
                    }
                });
            }
        });
        let dt = t0.elapsed();
        println!("{concurrent:>18} {:>12.1}", (concurrent * per_thread) as f64 / dt.as_secs_f64());
    }
}

// ---- §5.1 -------------------------------------------------------------------
fn sec5_cse() {
    println!("\n--- §5.1: common subexpression elimination (E11) ---");
    let build = || {
        let mut b = GraphBuilder::new();
        let x = b.constant(Tensor::fill_f32(vec![64, 64], 0.01));
        let mut outs = Vec::new();
        for _ in 0..4 {
            let mut h = x;
            for _ in 0..4 {
                h = b.matmul(h, x);
            }
            outs.push(h);
        }
        let sum = b.add_n(outs);
        let name = format!("{}:0", b.graph.node(sum.node).name);
        (b, name)
    };
    for enable in [false, true] {
        let (b, name) = build();
        let nodes_before = b.graph.len();
        // Folding off: the towers are const-rooted and would collapse
        // identically with or without CSE.
        let sess = Session::new(
            b.into_graph(),
            SessionOptions {
                enable_cse: enable,
                trace: true,
                enable_constant_folding: false,
                ..Default::default()
            },
        );
        let s = stats::bench(2, 20, || {
            sess.run(&[], &[&name], &[]).unwrap();
        });
        let executed = sess.last_trace().unwrap().len();
        println!(
            "cse={enable:<5} graph nodes {nodes_before:>3} kernels executed {executed:>3}  mean step {:?}",
            s.mean
        );
    }
}

// ---- §5.2 -------------------------------------------------------------------
fn sec5_recv_scheduling() {
    println!("\n--- §5.2: ASAP/ALAP Recv scheduling (E12) ---");
    // Wide fan-in across devices: many tensors received by a serial chain.
    let build = || {
        let mut b = GraphBuilder::new();
        let inputs: Vec<_> = (0..8)
            .map(|i| {
                b.with_device("/device:cpu:1", |b| {
                    let c = b.constant(Tensor::fill_f32(vec![128, 128], 0.01 * (i + 1) as f32));
                    b.tanh(c)
                })
            })
            .collect();
        let mut acc = b.with_device("/device:cpu:0", |b| b.relu(inputs[0]));
        for &x in &inputs[1..] {
            acc = b.with_device("/device:cpu:0", |b| {
                let m = b.matmul(acc, acc);
                b.add(m, x)
            });
        }
        let name = format!("{}:0", b.graph.node(acc.node).name);
        (b, name)
    };
    for enable in [false, true] {
        let (b, name) = build();
        // Static peak-residency estimate over the dev0 partition (the §5.2
        // measurable: the window intermediate results stay in memory).
        let (pruned, _, _) = rustflow::session::prune_for_run(&b.graph, &[], &[&name], &[]).unwrap();
        let mut placed = pruned;
        let devices = rustflow::device::DeviceSet::local(2, 1);
        // A "measured" cost model (§3.2.1): every intermediate here is a
        // 128×128 f32, so tell the analyzer the true transfer sizes.
        let mut cm = CostModel::new();
        rustflow::placement::place(&mut placed, &devices, &cm).unwrap();
        let (mut parts, _) = rustflow::partition::partition(&placed, &Default::default(), "").unwrap();
        for p in &parts {
            for id in p.graph.ids() {
                cm.record_output_bytes(&p.graph.node(id).name, (128 * 128 * 4) as f64);
            }
        }
        let mut edges = 0;
        if enable {
            edges = rustflow::passes::schedule_recvs_global(&mut parts, &cm)
                .unwrap()
                .control_edges_added;
        }
        let peak: f64 = parts
            .iter()
            .map(|p| rustflow::passes::schedule::estimate_peak_memory(&p.graph, &cm).unwrap())
            .fold(0.0, f64::max);
        println!(
            "recv_scheduling={enable:<5} est. peak resident bytes {peak:>12.0} (+{edges} control edges)"
        );
        // And the end-to-end step still runs correctly.
        // Folding off so the cross-device Recvs being scheduled stay real.
        let sess = Session::new(
            b.into_graph(),
            SessionOptions {
                devices: 2,
                enable_recv_scheduling: enable,
                enable_constant_folding: false,
                ..Default::default()
            },
        );
        sess.run(&[], &[&name], &[]).unwrap();
    }
}

// ---- §5.5 -------------------------------------------------------------------
fn sec5_lossy_compression() {
    println!("\n--- §5.5: lossy bf16 wire compression (E13) ---");
    for compress in [false, true] {
        let mut b = GraphBuilder::new();
        let examples = data::synthetic_classification(64, 16, 4, 0.2, 9);
        let (f, l) = data::batch_tensors(&examples).unwrap();
        let x = b.with_device("/device:cpu:0", |b| b.constant(f.clone()));
        let labels =
            b.with_device("/device:cpu:1", |b| b.constant(data::one_hot(l.as_i32().unwrap(), 4)));
        let (logits, vars) =
            b.with_device("/device:cpu:0", |b| models::mlp(b, x, &[16, 32, 4], 3)).unwrap();
        let loss = b.with_device("/device:cpu:1", |b| models::xent_loss(b, logits, labels)).unwrap();
        let train = Optimizer::sgd(0.5).minimize(&mut b, loss, &vars).unwrap();
        let tname = b.graph.node(train).name.clone();
        let lname = format!("{}:0", b.graph.node(loss.node).name);
        let inits: Vec<String> = b.init_ops.iter().map(|&i| b.graph.node(i).name.clone()).collect();
        let mut opts = SessionOptions { devices: 2, ..Default::default() };
        opts.partition.compress_all = compress;
        let sess = Session::new(b.into_graph(), opts);
        sess.run_targets(&inits.iter().map(|s| s.as_str()).collect::<Vec<_>>()).unwrap();
        let mut lv = f32::NAN;
        for _ in 0..60 {
            lv = sess.run(&[], &[&lname], &[&tname]).unwrap()[0].scalar_value_f32().unwrap();
        }
        let (_, pstats) = sess.step_stats(&[], &[&lname], &[&tname]).unwrap();
        println!(
            "compress={compress:<5} transfers={} compressed={} (bytes halved on those) final loss {lv:.4}",
            pstats.transfers, pstats.compressed_transfers
        );
    }
}

// ---- §6 ---------------------------------------------------------------------
fn sec6_inception_analog_vs_distbelief() {
    println!("\n--- §6: engine vs DistBelief-like parameter-server baseline (E10) ---");
    let (dim, classes, batch, steps) = (64usize, 10usize, 64usize, 40usize);
    let dims = [dim, 256, 256, 256, classes];
    let examples = data::synthetic_classification(batch, dim, classes, 0.3, 21);

    // Baseline: parameter-server pull/compute/push with serialization.
    let baseline = rustflow::baseline::BaselineTrainer::new(&dims, 0.1, 1).unwrap();
    baseline.step(&examples, classes).unwrap(); // warmup
    let t0 = Instant::now();
    let mut bl_loss = 0.0;
    for _ in 0..steps {
        bl_loss = baseline.step(&examples, classes).unwrap();
    }
    let bl_dt = t0.elapsed();
    let (pulled, pushed) = baseline.wire_bytes();

    // RustFlow: same model, same kernels, dataflow engine.
    let mut b = GraphBuilder::new();
    let (f, l) = data::batch_tensors(&examples).unwrap();
    let x = b.constant(f);
    let y = b.constant(data::one_hot(l.as_i32().unwrap(), classes));
    let (logits, vars) = models::mlp(&mut b, x, &dims, 1).unwrap();
    let loss = models::xent_loss(&mut b, logits, y).unwrap();
    let train = Optimizer::sgd(0.1).minimize(&mut b, loss, &vars).unwrap();
    let tname = b.graph.node(train).name.clone();
    let lname = format!("{}:0", b.graph.node(loss.node).name);
    let inits: Vec<String> = b.init_ops.iter().map(|&i| b.graph.node(i).name.clone()).collect();
    let sess = Session::new(
        b.into_graph(),
        SessionOptions { devices: 1, threads_per_device: 4, ..Default::default() },
    );
    sess.run_targets(&inits.iter().map(|s| s.as_str()).collect::<Vec<_>>()).unwrap();
    sess.run_targets(&[&tname]).unwrap(); // warmup/compile
    let t0 = Instant::now();
    let mut rf_loss = 0.0;
    for _ in 0..steps {
        rf_loss = sess.run(&[], &[&lname], &[&tname]).unwrap()[0].scalar_value_f32().unwrap();
    }
    let rf_dt = t0.elapsed();
    println!(
        "distbelief-like: {steps} steps in {bl_dt:?} ({:.1} steps/s), loss {bl_loss:.4}, wire {pulled}+{pushed} bytes",
        steps as f64 / bl_dt.as_secs_f64()
    );
    println!(
        "rustflow:        {steps} steps in {rf_dt:?} ({:.1} steps/s), loss {rf_loss:.4}",
        steps as f64 / rf_dt.as_secs_f64()
    );
    println!(
        "speedup: {:.2}x (paper reports 6x for Inception/DistBelief at datacenter scale)",
        bl_dt.as_secs_f64() / rf_dt.as_secs_f64()
    );
}

// ---- §4.6 --------------------------------------------------------------------
fn sec46_queue_prefetch() {
    println!("\n--- §4.6: input prefetch queue (E14) ---");
    // Simulated slow reader: Print-free busywork via big Shuffle. Compare
    // step latency with reader inlined vs prefetched through a queue by a
    // background client thread.
    let build = |use_queue: bool| {
        let mut b = GraphBuilder::new();
        let reader = {
            let big = b.constant(Tensor::fill_f32(vec![512, 64], 0.5)); // "I/O"
            let shuffled = b.op1("Shuffle", "reader", vec![big], vec![("seed", AttrValue::I64(1))]).unwrap();
            b.slice(shuffled, vec![0, 0], vec![64, 64])
        };
        let (consume_input, enq_name, deq_extra) = if use_queue {
            let q = b
                .op1(
                    "FIFOQueue",
                    "q",
                    vec![],
                    vec![
                        ("capacity", AttrValue::I64(64)),
                        ("component_types", AttrValue::ListType(vec![DType::F32])),
                    ],
                )
                .unwrap();
            let enq = b.op("Enqueue", "enq", vec![q, reader], vec![]).unwrap();
            let deq = b
                .op(
                    "Dequeue",
                    "deq",
                    vec![q],
                    vec![("component_types", AttrValue::ListType(vec![DType::F32]))],
                )
                .unwrap();
            (rustflow::Endpoint::new(deq, 0), Some(b.graph.node(enq).name.clone()), true)
        } else {
            (reader, None, false)
        };
        let _ = deq_extra;
        let mut h = consume_input;
        for _ in 0..3 {
            h = b.matmul(h, consume_input);
            h = b.tanh(h);
        }
        let out = b.reduce_sum(h, None);
        let name = format!("{}:0", b.graph.node(out.node).name);
        (b, name, enq_name)
    };
    // Inline reader.
    let (b, name, _) = build(false);
    let sess = Session::new(b.into_graph(), SessionOptions::default());
    let s_inline = stats::bench(2, 30, || {
        sess.run(&[], &[&name], &[]).unwrap();
    });
    // Prefetched reader: a producer fills the queue AHEAD of the measured
    // consumer steps (the §4.6 pattern with the producer's cadence
    // decoupled — here fully ahead, the best case prefetching converges to).
    let (b, name, enq) = build(true);
    let enq = enq.unwrap();
    let sess = Arc::new(Session::new(
        b.into_graph(),
        SessionOptions { threads_per_device: 4, ..Default::default() },
    ));
    let iters = 30usize;
    for _ in 0..iters + 2 {
        sess.run_targets(&[&enq]).unwrap();
    }
    let s_queue = stats::bench(2, iters, || {
        sess.run(&[], &[&name], &[]).unwrap();
    });
    println!("inline reader:    mean step {:?}", s_inline.mean);
    println!("prefetch queue:   mean step {:?}", s_queue.mean);
}

// ---- §9.2 --------------------------------------------------------------------
fn sec92_eeg_trace() {
    println!("\n--- §9.2: EEG-style trace (E15) ---");
    let mut b = GraphBuilder::new();
    let examples = data::synthetic_classification(64, 32, 4, 0.3, 2);
    let (f, l) = data::batch_tensors(&examples).unwrap();
    let x = b.constant(f);
    let y = b.constant(data::one_hot(l.as_i32().unwrap(), 4));
    let (logits, vars) = models::mlp(&mut b, x, &[32, 64, 4], 5).unwrap();
    let loss = models::xent_loss(&mut b, logits, y).unwrap();
    let train = Optimizer::sgd(0.1).minimize(&mut b, loss, &vars).unwrap();
    let tname = b.graph.node(train).name.clone();
    let inits: Vec<String> = b.init_ops.iter().map(|&i| b.graph.node(i).name.clone()).collect();
    let sess = Session::new(
        b.into_graph(),
        SessionOptions { devices: 2, trace: true, ..Default::default() },
    );
    sess.run_targets(&inits.iter().map(|s| s.as_str()).collect::<Vec<_>>()).unwrap();
    sess.run_targets(&[&tname]).unwrap();
    let trace = sess.last_trace().unwrap();
    let path = std::env::temp_dir().join("rustflow_trace.json");
    std::fs::write(&path, trace.to_chrome_trace()).unwrap();
    println!("{} kernel spans captured; chrome trace at {}", trace.len(), path.display());
    print!("{}", trace.summary());
}
