//! Partitioning benches (E4 support): Send/Recv insertion cost and the
//! canonicalization win on transfer counts.

use rustflow::device::DeviceSet;
use rustflow::partition::{partition, PartitionOptions};
use rustflow::placement::{place, CostModel};
use rustflow::util::rng::Pcg32;
use rustflow::util::stats;
use rustflow::{GraphBuilder, Tensor};

fn cross_device_graph(nodes: usize, devices: usize, seed: u64) -> rustflow::Graph {
    let mut rng = Pcg32::new(seed);
    let mut b = GraphBuilder::new();
    let mut pool = Vec::new();
    for d in 0..devices {
        let c = b.with_device(&format!("/device:cpu:{d}"), |b| {
            b.constant(Tensor::fill_f32(vec![16, 16], 0.1))
        });
        pool.push(c);
    }
    for i in 0..nodes {
        let a = pool[rng.index(pool.len())];
        let c = pool[rng.index(pool.len())];
        let dev = format!("/device:cpu:{}", i % devices);
        let v = b.with_device(&dev, |b| if rng.next_below(2) == 0 { b.add(a, c) } else { b.mul(a, c) });
        pool.push(v);
    }
    let ds = DeviceSet::local(devices, 1);
    place(&mut b.graph, &ds, &CostModel::new()).unwrap();
    b.graph
}

fn main() {
    for (nodes, devices) in [(200usize, 2usize), (200, 4), (2000, 4)] {
        let g = cross_device_graph(nodes, devices, 3);
        let s = stats::bench(2, 20, || {
            partition(&g, &PartitionOptions::default(), "").unwrap();
        });
        stats::report_throughput(
            &format!("partition/{nodes}nodes_{devices}dev"),
            &s,
            nodes as f64,
            "nodes",
        );
        let (_, canon) = partition(&g, &PartitionOptions::default(), "").unwrap();
        let (_, naive) = partition(
            &g,
            &PartitionOptions { canonicalize: false, ..Default::default() },
            "",
        )
        .unwrap();
        println!(
            "partition/canonicalization_{nodes}_{devices}dev: {} transfers vs naive {} ({:.2}x fewer)",
            canon.transfers,
            naive.transfers,
            naive.transfers as f64 / canon.transfers.max(1) as f64
        );
    }
}
