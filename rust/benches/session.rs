//! Session-level benches: MLP train step (the Fig-7 workhorse), feed/fetch
//! overhead, compile-cache effectiveness.

use rustflow::optim::Optimizer;
use rustflow::util::stats;
use rustflow::{data, models, DType, GraphBuilder, Session, SessionOptions, Tensor};

fn main() {
    // MLP train step end to end.
    for (dim, hidden) in [(64usize, 128usize), (128, 512)] {
        let mut b = GraphBuilder::new();
        let examples = data::synthetic_classification(64, dim, 10, 0.3, 2);
        let (f, l) = data::batch_tensors(&examples).unwrap();
        let x = b.constant(f);
        let y = b.constant(data::one_hot(l.as_i32().unwrap(), 10));
        let (logits, vars) = models::mlp(&mut b, x, &[dim, hidden, 10], 5).unwrap();
        let loss = models::xent_loss(&mut b, logits, y).unwrap();
        let train = Optimizer::sgd(0.1).minimize(&mut b, loss, &vars).unwrap();
        let tname = b.graph.node(train).name.clone();
        let inits: Vec<String> = b.init_ops.iter().map(|&i| b.graph.node(i).name.clone()).collect();
        let sess = Session::new(
            b.into_graph(),
            SessionOptions { threads_per_device: 4, ..Default::default() },
        );
        sess.run_targets(&inits.iter().map(|s| s.as_str()).collect::<Vec<_>>()).unwrap();
        let s = stats::bench(3, 30, || {
            sess.run_targets(&[&tname]).unwrap();
        });
        stats::report(&format!("session/mlp_train_{dim}x{hidden}"), &s);
    }
    // Feed/fetch overhead.
    {
        let mut b = GraphBuilder::new();
        let x = b.placeholder("x", DType::F32).unwrap();
        let y = b.neg(x);
        let name = format!("{}:0", b.graph.node(y.node).name);
        let sess = Session::new(b.into_graph(), SessionOptions::default());
        let input = Tensor::fill_f32(vec![64, 64], 1.0);
        let s = stats::bench(10, 200, || {
            sess.run(&[("x", input.clone())], &[&name], &[]).unwrap();
        });
        stats::report("session/feed_fetch_64x64", &s);
    }
    // First-run compile cost vs cached step.
    {
        let build = || {
            let mut b = GraphBuilder::new();
            let x = b.constant(Tensor::fill_f32(vec![32, 32], 0.1));
            let mut h = x;
            for _ in 0..50 {
                h = b.tanh(h);
            }
            let name = format!("{}:0", b.graph.node(h.node).name);
            (b, name)
        };
        let s_first = stats::bench(0, 20, || {
            let (b, name) = build();
            let sess = Session::new(b.into_graph(), SessionOptions::default());
            sess.run(&[], &[&name], &[]).unwrap();
        });
        stats::report("session/cold_run_50nodes(compile+run)", &s_first);
        let (b, name) = build();
        let sess = Session::new(b.into_graph(), SessionOptions::default());
        sess.run(&[], &[&name], &[]).unwrap();
        let s_cached = stats::bench(5, 50, || {
            sess.run(&[], &[&name], &[]).unwrap();
        });
        stats::report("session/warm_run_50nodes(cached)", &s_cached);
    }
}
