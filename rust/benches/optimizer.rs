//! §5 optimizer ablation bench: one deep elementwise-chain model run with
//! every pass combination that matters, reporting per-config step latency
//! and the full-pipeline speedup over passes-disabled (the acceptance bar
//! is ≥1.3×). Also writes the machine-readable `BENCH_optimizer.json`
//! (path overridable via `BENCH_OPTIMIZER_JSON`; `scripts/bench.sh` points
//! it at the repo root) — the start of the perf trajectory.

use rustflow::util::json::Json;
use rustflow::util::stats;
use rustflow::{DType, GraphBuilder, Session, SessionOptions, Tensor};

/// A deep elementwise chain over a fed vector, salted with the patterns
/// each pass eats: `*1`/`+0` identities (simplification), a const subtree
/// (folding), and long fusable runs (fusion). Every op is elementwise, so
/// passes-off cost ≈ N kernel launches + N intermediate tensors.
fn chain_model(depth: usize) -> (GraphBuilder, String) {
    let mut b = GraphBuilder::new();
    let x = b.placeholder("x", DType::F32).unwrap();
    let one = b.scalar(1.0);
    let zero = b.scalar(0.0);
    let half = b.scalar(0.5);
    let c = {
        let c1 = b.scalar(3.0);
        let c2 = b.scalar(2.0);
        let p = b.mul(c1, c2);
        b.sqrt(p) // const subtree → folds to one literal
    };
    let mut h = x;
    for i in 0..depth {
        h = match i % 6 {
            0 => b.mul(h, half),
            1 => b.add(h, c),
            2 => b.neg(h),
            3 => b.mul(h, one),
            4 => b.add(h, zero),
            _ => b.op1("Abs", "Abs", vec![h], vec![]).unwrap(),
        };
    }
    let name = format!("{}:0", b.graph.node(h.node).name);
    (b, name)
}

fn options(fold: bool, simplify: bool, cse: bool, fuse: bool) -> SessionOptions {
    SessionOptions {
        enable_constant_folding: fold,
        enable_arithmetic_simplification: simplify,
        enable_cse: cse,
        enable_elementwise_fusion: fuse,
        ..Default::default()
    }
}

fn main() {
    let depth = 96usize;
    let elements = 1usize << 16; // 256 KiB per intermediate at f32
    let input = Tensor::fill_f32(vec![elements], 0.25);
    let configs: &[(&str, bool, bool, bool, bool)] = &[
        ("all_off", false, false, false, false),
        ("fold_only", true, false, false, false),
        ("simplify_only", false, true, false, false),
        ("cse_only", false, false, true, false),
        ("fuse_only", false, false, false, true),
        ("full", true, true, true, true),
    ];

    let mut results = Json::arr();
    let mut mean_us_of = std::collections::HashMap::new();
    for &(name, fold, simplify, cse, fuse) in configs {
        let (b, oname) = chain_model(depth);
        let sess = Session::new(b.into_graph(), options(fold, simplify, cse, fuse));
        // First run compiles (and optimizes); keep it out of the samples.
        let first = sess.run(&[("x", input.clone())], &[&oname], &[]).unwrap();
        assert!(first[0].as_f32().unwrap()[0].is_finite());
        let s = stats::bench(5, 40, || {
            sess.run(&[("x", input.clone())], &[&oname], &[]).unwrap();
        });
        stats::report(&format!("optimizer/chain{depth}x{elements}/{name}"), &s);
        let mean_us = s.mean.as_secs_f64() * 1e6;
        mean_us_of.insert(name, mean_us);
        let row = Json::obj()
            .set("config", name)
            .set("mean_us", mean_us)
            .set("p50_us", s.p50.as_secs_f64() * 1e6)
            .set("p95_us", s.p95.as_secs_f64() * 1e6)
            .set("iters", s.iters as i64);
        results.push(row);
    }

    let speedup = mean_us_of["all_off"] / mean_us_of["full"];
    println!("optimizer/chain{depth}x{elements}: full pipeline {speedup:.2}x vs passes-off");

    // Cross-check the acceptance criterion here too so a perf regression
    // fails loudly when the bench is run, not just in the JSON.
    assert!(
        speedup >= 1.3,
        "full optimizer pipeline must be >= 1.3x over passes-off, got {speedup:.2}x"
    );

    let out = Json::obj()
        .set("bench", "optimizer_ablation")
        .set("model", "deep-elementwise-chain")
        .set("depth", depth as i64)
        .set("elements", elements as i64)
        .set("results", results)
        .set("speedup_full_vs_off", speedup);
    let path = std::env::var("BENCH_OPTIMIZER_JSON")
        .unwrap_or_else(|_| "BENCH_optimizer.json".to_string());
    std::fs::write(&path, out.render() + "\n").expect("write bench json");
    println!("wrote {path}");
}
