//! Step-memory-planner bench: steps/sec and *real* heap bytes allocated
//! per step with planning on vs off, measured by a counting global
//! allocator — the §9.2 "find the allocation hot spots" number, made a
//! regression gate. The model is a deep elementwise/matmul stack (matmul
//! breaks fusion chains, so plenty of intermediates survive the
//! optimizer), const-rooted so shapes are static (folding pinned off, the
//! established idiom for const-rooted benches); a fed variant reports the
//! dynamic-slot path. Asserts the ISSUE acceptance bar: planning-on
//! allocates ≥ 2× fewer heap bytes per step than planning-off, with
//! identical results (1e-6; fusion is enabled). Writes
//! `BENCH_memory.json` (path via `BENCH_MEMORY_JSON`; `scripts/bench.sh`
//! points it at the repo root).

use rustflow::util::json::Json;
use rustflow::util::stats;
use rustflow::{GraphBuilder, Session, SessionOptions, Tensor};
use std::alloc::{GlobalAlloc, Layout, System};
use std::sync::atomic::{AtomicU64, Ordering};

/// Counts every byte the process allocates (alloc + realloc growth).
struct CountingAlloc;

static ALLOCATED_BYTES: AtomicU64 = AtomicU64::new(0);

unsafe impl GlobalAlloc for CountingAlloc {
    unsafe fn alloc(&self, layout: Layout) -> *mut u8 {
        ALLOCATED_BYTES.fetch_add(layout.size() as u64, Ordering::Relaxed);
        System.alloc(layout)
    }

    unsafe fn dealloc(&self, ptr: *mut u8, layout: Layout) {
        System.dealloc(ptr, layout)
    }

    unsafe fn realloc(&self, ptr: *mut u8, layout: Layout, new_size: usize) -> *mut u8 {
        ALLOCATED_BYTES.fetch_add(new_size.saturating_sub(layout.size()) as u64, Ordering::Relaxed);
        System.realloc(ptr, layout, new_size)
    }
}

#[global_allocator]
static ALLOC: CountingAlloc = CountingAlloc;

const DIM: usize = 64;
const DEPTH: usize = 24;

/// h ← Tanh(MatMul(h, W_l) + B_l), repeated. Const-rooted (static shapes)
/// unless `fed`, in which case x comes from a feed (dynamic slots).
fn stack_model(fed: bool) -> (GraphBuilder, String) {
    let mut b = GraphBuilder::new();
    let x = if fed {
        b.placeholder("x", rustflow::DType::F32).unwrap()
    } else {
        b.constant(Tensor::fill_f32(vec![DIM, DIM], 0.01))
    };
    let mut h = x;
    for l in 0..DEPTH {
        let w = b.constant(Tensor::fill_f32(vec![DIM, DIM], 0.02 + l as f32 * 1e-4));
        let bias = b.constant(Tensor::fill_f32(vec![DIM, DIM], 0.001));
        let mm = b.matmul(h, w);
        let a = b.add(mm, bias);
        h = b.tanh(a);
    }
    let name = format!("{}:0", b.graph.node(h.node).name);
    (b, name)
}

fn options(planning: bool) -> SessionOptions {
    SessionOptions {
        enable_memory_planning: planning,
        // Const-rooted: folding would evaluate the whole stack at build
        // time; pin it off so the bench measures run-time execution.
        enable_constant_folding: false,
        ..Default::default()
    }
}

struct Measured {
    mean_us: f64,
    bytes_per_step: f64,
    out: Tensor,
}

fn measure(fed: bool, planning: bool) -> Measured {
    let (b, name) = stack_model(fed);
    let sess = Session::new(b.into_graph(), options(planning));
    let feed = Tensor::fill_f32(vec![DIM, DIM], 0.01);
    let run = |sess: &Session| -> Tensor {
        let feeds: Vec<(&str, Tensor)> =
            if fed { vec![("x", feed.clone())] } else { vec![] };
        sess.run(&feeds, &[&name], &[]).unwrap().remove(0)
    };
    // Warm: compile the step and fill the arena pool.
    let out = run(&sess);
    for _ in 0..3 {
        run(&sess);
    }
    // Bytes: count across a fixed batch of steps.
    let steps = 30u64;
    let before = ALLOCATED_BYTES.load(Ordering::Relaxed);
    for _ in 0..steps {
        run(&sess);
    }
    let bytes_per_step =
        (ALLOCATED_BYTES.load(Ordering::Relaxed) - before) as f64 / steps as f64;
    // Time separately (the counter's overhead is symmetric anyway).
    let s = stats::bench(5, 30, || {
        run(&sess);
    });
    Measured { mean_us: s.mean.as_secs_f64() * 1e6, bytes_per_step, out }
}

fn main() {
    let mut results = Json::arr();
    let mut summary: Vec<(String, f64, f64)> = Vec::new();
    for (label, fed) in [("static", false), ("fed", true)] {
        let on = measure(fed, true);
        let off = measure(fed, false);
        assert!(
            on.out.allclose(&off.out, 1e-6, 1e-6),
            "{label}: planning changed results"
        );
        let bytes_ratio = off.bytes_per_step / on.bytes_per_step.max(1.0);
        let speedup = off.mean_us / on.mean_us;
        println!(
            "memory/{label}{DEPTH}x{DIM}: planning-on {:.0} B/step vs off {:.0} B/step \
             ({bytes_ratio:.2}x fewer), {:.0}us vs {:.0}us ({speedup:.2}x)",
            on.bytes_per_step, off.bytes_per_step, on.mean_us, off.mean_us
        );
        results.push(
            Json::obj()
                .set("model", label)
                .set("depth", DEPTH as i64)
                .set("dim", DIM as i64)
                .set("bytes_per_step_on", on.bytes_per_step)
                .set("bytes_per_step_off", off.bytes_per_step)
                .set("bytes_ratio_off_over_on", bytes_ratio)
                .set("mean_us_on", on.mean_us)
                .set("mean_us_off", off.mean_us)
                .set("speedup_on_vs_off", speedup),
        );
        summary.push((label.to_string(), bytes_ratio, speedup));
    }

    // Acceptance bar (ISSUE 3): ≥ 2× fewer heap bytes per step on the
    // deep elementwise/matmul graph with planning on.
    let static_ratio = summary.iter().find(|(l, ..)| l == "static").unwrap().1;
    assert!(
        static_ratio >= 2.0,
        "memory planning must cut heap bytes/step by >= 2x on the static stack, got {static_ratio:.2}x"
    );

    let out = Json::obj()
        .set("bench", "memory_planner")
        .set("model", "matmul-bias-tanh-stack")
        .set("results", results)
        .set("bytes_ratio_static", static_ratio);
    let path = std::env::var("BENCH_MEMORY_JSON")
        .unwrap_or_else(|_| "BENCH_memory.json".to_string());
    std::fs::write(&path, out.render() + "\n").expect("write bench json");
    println!("wrote {path}");
}
