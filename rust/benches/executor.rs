//! Executor micro-benchmarks: dispatch overhead per node (§3.1's ready
//! queue), deep chains vs wide fan-outs, and control-flow loop overhead.

use rustflow::util::stats;
use rustflow::{GraphBuilder, Session, SessionOptions, Tensor};

/// These are *executor* micro-benches: the §5 optimizer would fold or fuse
/// the toy graphs away and leave nothing to dispatch, so it stays off.
fn raw_executor_options() -> SessionOptions {
    SessionOptions {
        enable_constant_folding: false,
        enable_arithmetic_simplification: false,
        enable_cse: false,
        enable_elementwise_fusion: false,
        ..Default::default()
    }
}

fn main() {
    // Chain of N cheap nodes: measures per-node dispatch overhead.
    for n in [100usize, 1000] {
        let mut b = GraphBuilder::new();
        let mut x = b.scalar(1.0);
        for _ in 0..n {
            x = b.neg(x);
        }
        let name = format!("{}:0", b.graph.node(x.node).name);
        let sess = Session::new(b.into_graph(), raw_executor_options());
        let s = stats::bench(3, 30, || {
            sess.run(&[], &[&name], &[]).unwrap();
        });
        stats::report_throughput(&format!("executor/chain_{n}"), &s, n as f64, "nodes");
    }
    // Wide fan-out (parallelism exposure).
    {
        let n = 512usize;
        let mut b = GraphBuilder::new();
        let x = b.scalar(1.0);
        let outs: Vec<_> = (0..n).map(|_| b.neg(x)).collect();
        let sum = b.add_n(outs);
        let name = format!("{}:0", b.graph.node(sum.node).name);
        let sess = Session::new(
            b.into_graph(),
            SessionOptions { threads_per_device: 4, ..raw_executor_options() },
        );
        let s = stats::bench(3, 30, || {
            sess.run(&[], &[&name], &[]).unwrap();
        });
        stats::report_throughput("executor/fanout_512", &s, n as f64, "nodes");
    }
    // While loop: per-iteration tag machinery cost (§4.4).
    for iters in [10usize, 100] {
        let mut b = GraphBuilder::new();
        let zero = b.scalar(0.0);
        let lim = iters as f32;
        let exits = b
            .while_loop(
                "bench",
                vec![zero],
                move |b, v| {
                    let l = b.scalar(lim);
                    Ok(b.less(v[0], l))
                },
                |b, v| {
                    let one = b.scalar(1.0);
                    Ok(vec![b.add(v[0], one)])
                },
            )
            .unwrap();
        let name = format!("{}:0", b.graph.node(exits[0].node).name);
        let sess = Session::new(b.into_graph(), raw_executor_options());
        let s = stats::bench(3, 20, || {
            let out = sess.run(&[], &[&name], &[]).unwrap();
            assert_eq!(out[0].scalar_value_f32().unwrap(), lim);
        });
        stats::report_throughput(&format!("executor/while_loop_{iters}"), &s, iters as f64, "iters");
    }
    // Empty-ish run: session fixed overhead.
    {
        let mut b = GraphBuilder::new();
        let x = b.scalar(1.0);
        let name = format!("{}:0", b.graph.node(x.node).name);
        let sess = Session::new(b.into_graph(), raw_executor_options());
        let s = stats::bench(10, 200, || {
            sess.run(&[], &[&name], &[]).unwrap();
        });
        stats::report("session/run_overhead_1node", &s);
    }
    let _ = Tensor::scalar_f32(0.0);
}
