//! Queue benches (§4.6): FIFO/shuffle throughput and contention.

use rustflow::queue::{dequeue_blocking, enqueue_blocking, QueueImpl};
use rustflow::util::stats;
use rustflow::Tensor;

fn main() {
    for (label, q) in [
        ("queue/fifo", QueueImpl::fifo(1024, 1)),
        ("queue/shuffle", QueueImpl::shuffle(1024, 1, 16, 3)),
    ] {
        let t = Tensor::fill_f32(vec![64], 0.5);
        // Pre-fill to keep the shuffle threshold satisfied.
        for _ in 0..64 {
            enqueue_blocking(&q, vec![t.clone()]).unwrap();
        }
        let s = stats::bench(1000, 100_000, || {
            enqueue_blocking(&q, vec![t.clone()]).unwrap();
            dequeue_blocking(&q).unwrap();
        });
        stats::report(&format!("{label}/enq_deq_pair"), &s);
    }
    // Multi-producer multi-consumer throughput.
    {
        let q = QueueImpl::fifo(256, 1);
        let n_per = 20_000usize;
        let producers = 4;
        let t0 = std::time::Instant::now();
        std::thread::scope(|scope| {
            for _ in 0..producers {
                let q = q.clone();
                scope.spawn(move || {
                    let t = Tensor::scalar_f32(1.0);
                    for _ in 0..n_per {
                        enqueue_blocking(&q, vec![t.clone()]).unwrap();
                    }
                });
            }
            for _ in 0..producers {
                let q = q.clone();
                scope.spawn(move || {
                    for _ in 0..n_per {
                        dequeue_blocking(&q).unwrap();
                    }
                });
            }
        });
        let dt = t0.elapsed();
        println!(
            "queue/mpmc_4x4                                   {:>14.0} elems/s",
            (producers * n_per) as f64 / dt.as_secs_f64()
        );
    }
}
