//! Wire-serving throughput: sustained requests/sec over loopback TCP
//! through the model hub (NetClient → NetServer → ModelManager →
//! batched Session steps), at 8 and 16 closed-loop clients, with and
//! without a mid-run hot-swap to a second model version.
//!
//! Acceptance bar: the hot-swap is cheap — the worst 200ms throughput
//! window of the swap run stays within 20% of the no-swap run's median
//! window (asserted when the machine has ≥ 4 cores; recorded as
//! `assert_skipped` otherwise, since on tiny machines the deploy thread
//! itself visibly steals CPU from the clients).
//!
//!     cargo bench --bench serving_net
//!
//! Writes BENCH_serving_net.json (path from $BENCH_SERVING_NET_JSON,
//! set by scripts/bench.sh).

use rustflow::serving::{
    BatchConfig, ManagerOptions, ModelManager, NetClient, NetServer, WarmupRequest,
};
use rustflow::util::json::Json;
use rustflow::{models, DType, GraphBuilder, Session, SessionOptions, Tensor};
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::Arc;
use std::time::{Duration, Instant};

const DIM: usize = 32;
const HIDDEN: usize = 128;
const CLASSES: usize = 10;
const WINDOW: Duration = Duration::from_millis(200);
const WINDOWS: usize = 8;
const WARM: Duration = Duration::from_millis(300);

fn build_session(seed: u64) -> (Arc<Session>, String) {
    let mut b = GraphBuilder::new();
    let x = b.placeholder("x", DType::F32).unwrap();
    let (logits, _vars) = models::mlp(&mut b, x, &[DIM, HIDDEN, CLASSES], seed).unwrap();
    let fetch = format!("{}:0", b.graph.node(logits.node).name);
    let inits: Vec<String> = b.init_ops.iter().map(|&i| b.graph.node(i).name.clone()).collect();
    let session = Arc::new(Session::new(
        b.into_graph(),
        SessionOptions { threads_per_device: 4, intra_op_threads: 2, ..Default::default() },
    ));
    session.run_targets(&inits.iter().map(String::as_str).collect::<Vec<_>>()).unwrap();
    (session, fetch)
}

fn warmup_for(fetch: &str) -> Vec<WarmupRequest> {
    vec![WarmupRequest {
        feeds: vec![("x".to_string(), Tensor::fill_f32(vec![1, DIM], 0.5))],
        fetches: vec![fetch.to_string()],
    }]
}

struct Phase {
    windows: Vec<f64>,
    total_rps: f64,
    mean_batch_rows: f64,
}

/// One measured run: `clients` closed-loop TCP clients for
/// WARM + WINDOWS·WINDOW; when `swap`, v2 deploys (build + warm + swap +
/// drain, on its own thread) as the middle window opens.
fn run_phase(clients: usize, swap: bool) -> Phase {
    let manager = Arc::new(ModelManager::new(ManagerOptions {
        session: SessionOptions::default(), // versions bring their own sessions
        batch: BatchConfig {
            max_batch_size: 32,
            max_batch_delay: Duration::from_millis(2),
            queue_capacity: 4096,
            ..BatchConfig::default()
        },
    }));
    let (s1, fetch) = build_session(7);
    manager.deploy_session("bench", 1, s1, &warmup_for(&fetch)).unwrap();
    let server = NetServer::serve(Arc::clone(&manager), "127.0.0.1:0").unwrap();
    let addr = server.addr().to_string();

    let stop = Arc::new(AtomicBool::new(false));
    let completed = Arc::new(AtomicU64::new(0));
    let mut client_threads = Vec::new();
    for c in 0..clients {
        let addr = addr.clone();
        let fetch = fetch.clone();
        let stop = Arc::clone(&stop);
        let completed = Arc::clone(&completed);
        client_threads.push(std::thread::spawn(move || {
            let mut client = NetClient::connect(&addr).expect("connect");
            let mut i = 0usize;
            while !stop.load(Ordering::Relaxed) {
                i += 1;
                let v = ((c + 1) * i % 13) as f32 * 0.1;
                let input = Tensor::fill_f32(vec![1, DIM], v);
                client
                    .predict("bench", None, &[("x", input)], &[&fetch])
                    .expect("predict failed (hot-swaps must not fail requests)");
                completed.fetch_add(1, Ordering::Relaxed);
            }
        }));
    }

    std::thread::sleep(WARM);
    let bench_start = Instant::now();
    let start_count = completed.load(Ordering::Relaxed);
    let mut windows = Vec::with_capacity(WINDOWS);
    let mut swap_thread = None;
    for w in 0..WINDOWS {
        if swap && w == WINDOWS / 2 {
            let manager = Arc::clone(&manager);
            let fetch = fetch.clone();
            swap_thread = Some(std::thread::spawn(move || {
                let (s2, fetch2) = build_session(13);
                assert_eq!(fetch2, fetch);
                manager.deploy_session("bench", 2, s2, &warmup_for(&fetch)).unwrap();
            }));
        }
        let before = completed.load(Ordering::Relaxed);
        let t = Instant::now();
        std::thread::sleep(WINDOW);
        let n = completed.load(Ordering::Relaxed) - before;
        windows.push(n as f64 / t.elapsed().as_secs_f64());
    }
    let bench_count = completed.load(Ordering::Relaxed) - start_count;
    let total = bench_count as f64 / bench_start.elapsed().as_secs_f64();
    stop.store(true, Ordering::Relaxed);
    for t in client_threads {
        t.join().expect("client thread panicked");
    }
    if let Some(t) = swap_thread {
        t.join().expect("swap thread panicked");
    }
    let stats = manager.stats();
    let (mut batches, mut rows) = (0u64, 0u64);
    for s in &stats {
        batches += s.batch.batches;
        rows += s.batch.rows;
    }
    server.shutdown();
    manager.shutdown();
    Phase {
        windows,
        total_rps: total,
        mean_batch_rows: if batches == 0 { 0.0 } else { rows as f64 / batches as f64 },
    }
}

fn median(xs: &[f64]) -> f64 {
    let mut v = xs.to_vec();
    v.sort_by(|a, b| a.partial_cmp(b).unwrap());
    v[v.len() / 2]
}

fn main() {
    let cores = std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1);
    let assertable = cores >= 4;
    println!(
        "{:<28} {:>12} {:>14} {:>16} {:>12}",
        "config", "req/s", "median window", "worst swap win", "mean batch"
    );

    let mut configs = Json::arr();
    let mut all_ok = true;
    for clients in [8usize, 16] {
        let baseline = run_phase(clients, false);
        let swap = run_phase(clients, true);
        let base_median = median(&baseline.windows);
        let swap_min = swap.windows.iter().cloned().fold(f64::INFINITY, f64::min);
        let ratio = swap_min / base_median;
        let ok = ratio >= 0.8;
        if assertable {
            all_ok &= ok;
        }
        println!(
            "{:<28} {:>12.0} {:>14.0} {:>16.0} {:>12.1}",
            format!("clients={clients} (no swap)"),
            baseline.total_rps,
            base_median,
            f64::NAN,
            baseline.mean_batch_rows,
        );
        println!(
            "{:<28} {:>12.0} {:>14.0} {:>16.0} {:>12.1}",
            format!("clients={clients} (hot-swap)"),
            swap.total_rps,
            median(&swap.windows),
            swap_min,
            swap.mean_batch_rows,
        );

        let to_arr = |xs: &[f64]| {
            let mut a = Json::arr();
            for &x in xs {
                a.push(x);
            }
            a
        };
        configs.push(
            Json::obj()
                .set("clients", clients)
                .set("baseline_rps", baseline.total_rps)
                .set("baseline_windows_rps", to_arr(&baseline.windows))
                .set("baseline_median_window_rps", base_median)
                .set("swap_rps", swap.total_rps)
                .set("swap_windows_rps", to_arr(&swap.windows))
                .set("swap_min_window_rps", swap_min)
                .set("swap_to_baseline_ratio", ratio)
                .set("mean_batch_rows_baseline", baseline.mean_batch_rows)
                .set("mean_batch_rows_swap", swap.mean_batch_rows)
                .set("ok", ok),
        );
    }

    let out = Json::obj()
        .set("bench", "serving_net")
        .set("model", format!("mlp {DIM}x{HIDDEN}x{CLASSES}"))
        .set("window_ms", WINDOW.as_millis() as u64)
        .set("windows", WINDOWS)
        .set("cores", cores)
        .set("assert_skipped", !assertable)
        .set("configs", configs);
    let path = std::env::var("BENCH_SERVING_NET_JSON")
        .unwrap_or_else(|_| "BENCH_serving_net.json".to_string());
    std::fs::write(&path, out.render()).expect("write bench json");
    println!("\nwrote {path}");

    if assertable {
        assert!(
            all_ok,
            "hot-swap cost the serving path more than 20% of a throughput window \
             (see {path} for per-window rates)"
        );
        println!("serving_net: OK (worst swap window within 20% of baseline median)");
    } else {
        println!("serving_net: assertion skipped ({cores} cores < 4)");
    }
}
