//! Sparse embedding training bench (§4.2): steps/sec and bytes-on-wire
//! for 2 synchronous replicas updating a [VOCAB, DIM] embedding table
//! through one parameter-server shard, native `GradEntry::Sparse`
//! (IndexedSlices straight off the graph) vs the dense wire path
//! (densify + full-table push) at fixed work.
//!
//! Acceptance bar: with ≤ 10% of rows touched per step, the sparse path
//! sustains ≥ 2x the dense path's steps/sec (skipped — recorded in the
//! JSON — when a run touches more rows than that).
//!
//!     cargo bench --bench embeddings
//!
//! Writes BENCH_embeddings.json (path from $BENCH_EMBEDDINGS_JSON, set
//! by scripts/bench.sh).

use rustflow::distributed::{DistTrainer, DistTrainerOptions, ParamServer, PsOptions};
use rustflow::optim::Optimizer;
use rustflow::util::json::Json;
use rustflow::{DType, GraphBuilder, SessionOptions};
use std::time::{Duration, Instant};

const VOCAB: usize = 8192;
const DIM: usize = 32;
const BATCH: usize = 64;
/// Lookups cycle through this many leading rows, so steps revisit rows
/// and the loss (Σ gathered²) decays — a convergence check, not noise.
const HOT_ROWS: usize = 512;
const REPLICAS: usize = 2;
const STEPS: usize = 20;

struct RunOut {
    steps_per_sec: f64,
    wire_bytes: u64,
    first_loss: f32,
    last_loss: f32,
    elapsed: Duration,
}

/// `BATCH` distinct ids for (step, replica) — replicas touch disjoint
/// blocks within a step, every block ≤ 10% of the vocabulary.
fn step_ids(step: usize, replica: usize) -> Vec<i64> {
    let base = ((step * REPLICAS + replica) * BATCH) % HOT_ROWS;
    (0..BATCH).map(|k| ((base + k) % HOT_ROWS) as i64).collect()
}

/// Synchronous 2-replica run over a fresh shard: same graph either way;
/// `native_sparse` picks which wire form the Gather gradient takes.
fn run(native_sparse: bool) -> RunOut {
    let ps = ParamServer::new(PsOptions {
        opt: Optimizer::sgd(0.25),
        sync_replicas: Some(REPLICAS),
        ..Default::default()
    });
    let addr = ps.serve("127.0.0.1:0").unwrap().to_string();

    let t0 = Instant::now();
    let losses: Vec<Vec<f32>> = std::thread::scope(|scope| {
        let handles: Vec<_> = (0..REPLICAS)
            .map(|r| {
                let addr = addr.clone();
                scope.spawn(move || {
                    let mut b = GraphBuilder::new();
                    let emb = b
                        .variable_uniform("emb", vec![VOCAB, DIM], -0.5, 0.5, 9)
                        .unwrap();
                    let ids = b.placeholder("ids", DType::I64).unwrap();
                    let rows =
                        b.op1("Gather", "lookup", vec![emb, ids], vec![]).unwrap();
                    let sq = b.square(rows);
                    let loss = b.reduce_sum(sq, None);
                    let mut t = DistTrainer::new(
                        b,
                        loss,
                        &[emb],
                        r as u32,
                        &[addr],
                        DistTrainerOptions {
                            compress: false,
                            native_sparse,
                            ..Default::default()
                        },
                        SessionOptions::default(),
                    )
                    .unwrap();
                    t.init_params().unwrap();
                    (0..STEPS)
                        .map(|s| {
                            let ids = step_ids(s, r);
                            let n = ids.len();
                            let feed =
                                rustflow::tensor::Tensor::from_i64(vec![n], ids).unwrap();
                            t.step(&[("ids", feed)]).unwrap()
                        })
                        .collect::<Vec<f32>>()
                })
            })
            .collect();
        handles.into_iter().map(|h| h.join().expect("replica thread panicked")).collect()
    });
    let elapsed = t0.elapsed();
    let wire_bytes = ps.wire_bytes();
    ps.shutdown();
    RunOut {
        steps_per_sec: STEPS as f64 / elapsed.as_secs_f64(),
        wire_bytes,
        first_loss: losses[0][0],
        last_loss: losses[0][STEPS - 1],
        elapsed,
    }
}

fn main() {
    let touched_fraction = BATCH as f64 / VOCAB as f64;
    println!(
        "{:<28} {:>10} {:>12} {:>10} {:>10}",
        "config", "steps/s", "wire KiB", "loss[0]", "loss[-1]"
    );
    let sparse = run(true);
    let dense = run(false);
    for (label, r) in [("sparse (IndexedSlices)", &sparse), ("dense (densified push)", &dense)] {
        println!(
            "{:<28} {:>10.1} {:>12.1} {:>10.2} {:>10.2}",
            label,
            r.steps_per_sec,
            r.wire_bytes as f64 / 1024.0,
            r.first_loss,
            r.last_loss,
        );
    }

    let speedup = sparse.steps_per_sec / dense.steps_per_sec;
    let wire_ratio = dense.wire_bytes as f64 / sparse.wire_bytes as f64;
    let converged = sparse.last_loss < sparse.first_loss && dense.last_loss < dense.first_loss;
    // The ≥2x bar is only claimed for genuinely sparse updates.
    let assertable = touched_fraction <= 0.10;
    println!(
        "sparse wire path: {speedup:.2}x steps/s, {wire_ratio:.1}x fewer wire bytes \
         ({:.2}% rows touched/step)",
        touched_fraction * 100.0
    );

    let out = Json::obj()
        .set("bench", "embeddings")
        .set("table", format!("{VOCAB}x{DIM}"))
        .set("batch", BATCH)
        .set("sync_replicas", REPLICAS)
        .set("steps", STEPS)
        .set("touched_fraction", touched_fraction)
        .set("sparse_steps_per_sec", sparse.steps_per_sec)
        .set("dense_steps_per_sec", dense.steps_per_sec)
        .set("speedup", speedup)
        .set("sparse_wire_bytes", sparse.wire_bytes)
        .set("dense_wire_bytes", dense.wire_bytes)
        .set("sparse_elapsed_ms", sparse.elapsed.as_millis() as u64)
        .set("dense_elapsed_ms", dense.elapsed.as_millis() as u64)
        .set("sparse_last_loss", sparse.last_loss as f64)
        .set("dense_last_loss", dense.last_loss as f64)
        .set("converged", converged)
        .set("assert_skipped", !assertable);

    let path = std::env::var("BENCH_EMBEDDINGS_JSON")
        .unwrap_or_else(|_| "BENCH_embeddings.json".to_string());
    std::fs::write(&path, out.render()).expect("write bench json");
    println!("\nwrote {path}");

    assert!(converged, "both wire paths must reduce the loss");
    assert!(
        sparse.wire_bytes < dense.wire_bytes,
        "sparse pushes must spend fewer bytes than dense ({} vs {})",
        sparse.wire_bytes,
        dense.wire_bytes
    );
    if assertable {
        assert!(
            speedup >= 2.0,
            "sparse wire path must be >= 2x dense steps/s at {:.2}% touched rows (got {speedup:.2}x)",
            touched_fraction * 100.0
        );
        println!("embeddings: OK ({speedup:.2}x steps/s, {wire_ratio:.1}x fewer wire bytes)");
    } else {
        println!(
            "embeddings: OK ({speedup:.2}x steps/s; >=2x assertion skipped \
             ({:.0}% rows touched/step > 10%))",
            touched_fraction * 100.0
        );
    }
}
