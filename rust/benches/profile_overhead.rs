//! Continuous-profiler overhead bench: steps/sec through the same
//! matmul/bias/tanh stack with `SessionOptions::profile_window` 0 (no
//! profiling, no tracing) vs the default 32 (per-step trace collection,
//! StepStats distillation, ring insert — the always-on `/statusz` feed).
//!
//! Acceptance bar: profiling stays within 10% of the unprofiled run on
//! real kernels. That is what justifies shipping it on by default.
//!
//!     cargo bench --bench profile_overhead
//!
//! Writes BENCH_profile_overhead.json (path from
//! $BENCH_PROFILE_OVERHEAD_JSON, set by scripts/bench.sh).

use rustflow::util::json::Json;
use rustflow::util::stats;
use rustflow::{GraphBuilder, Session, SessionOptions, Tensor};
use std::time::Duration;

fn filled(r: usize, c: usize, seed: u32) -> Tensor {
    let v: Vec<f32> = (0..r * c)
        .map(|i| {
            let h = (i as u32).wrapping_mul(2654435761).wrapping_add(seed);
            ((h % 1000) as f32) * 0.002 - 1.0
        })
        .collect();
    Tensor::from_f32(vec![r, c], v).unwrap()
}

/// Steps/sec (and the fetched output for bit-identity checks) through a
/// `depth`-layer matmul/bias/tanh stack at width `dim`, with the
/// profiler keeping `window` steps (0 = off).
fn stack_steps_per_sec(dim: usize, depth: u32, window: usize) -> (f64, Tensor) {
    let mut b = GraphBuilder::new();
    let x = b.placeholder("x", rustflow::DType::F32).unwrap();
    let mut h = x;
    for l in 0..depth {
        let w = b.constant(filled(dim, dim, 100 + l));
        let bias = b.constant(filled(1, dim, 200 + l));
        let mm = b.matmul(h, w);
        let s = b.add(mm, bias);
        h = b.tanh(s);
    }
    let fetch = format!("{}:0", b.graph.node(h.node).name);
    let sess = Session::new(
        b.into_graph(),
        SessionOptions { profile_window: window, ..Default::default() },
    );
    let feed = filled(dim, dim, 7);
    let run = || sess.run(&[("x", feed.clone())], &[&fetch], &[]).unwrap().remove(0);
    let out = run(); // warm: compile + fill arena pool
    let s = stats::bench_for(3, Duration::from_secs(2), || {
        run();
    });
    if window > 0 {
        let p = sess.profiler().expect("profiling enabled");
        assert!(p.steps_observed() > 0, "profiled run observed no steps");
        assert!(
            p.node_rollups().iter().any(|r| r.total_us > 0),
            "rollups must show nonzero self-times"
        );
    } else {
        assert!(sess.profiler().is_none(), "window 0 must disable profiling");
    }
    (1.0 / s.mean.as_secs_f64(), out)
}

fn main() {
    // The production shape: 6 layers of 256x256. The profiler's per-step
    // cost (span records + StepStats fold + Arc push) is amortized over
    // ~180 MFLOP/step.
    let (off, out_off) = stack_steps_per_sec(256, 6, 0);
    let (on, out_on) = stack_steps_per_sec(256, 6, 32);
    assert_eq!(
        out_off.as_f32().unwrap(),
        out_on.as_f32().unwrap(),
        "profiling must not change results"
    );
    let overhead = off / on - 1.0;
    println!(
        "profile_overhead/stack 6x256: {off:.1} steps/s off, {on:.1} steps/s window=32 \
         ({:.1}% overhead)",
        overhead * 100.0
    );

    // Worst case: 48 layers of 16x16 — per-step distillation cost over
    // hundreds of tiny kernels. Reported, not asserted.
    let (tiny_off, _) = stack_steps_per_sec(16, 48, 0);
    let (tiny_on, _) = stack_steps_per_sec(16, 48, 32);
    let tiny_overhead = tiny_off / tiny_on - 1.0;
    println!(
        "profile_overhead/tiny 48x16: {tiny_off:.1} steps/s off, {tiny_on:.1} steps/s window=32 \
         ({:.1}% overhead)",
        tiny_overhead * 100.0
    );

    assert!(
        overhead <= 0.10,
        "continuous profiling on real kernels must stay within 10%, got {:.1}%",
        overhead * 100.0
    );

    let out = Json::obj()
        .set("bench", "profile_overhead")
        .set("stack_steps_per_sec_off", off)
        .set("stack_steps_per_sec_on", on)
        .set("stack_overhead", overhead)
        .set("tiny_steps_per_sec_off", tiny_off)
        .set("tiny_steps_per_sec_on", tiny_on)
        .set("tiny_overhead", tiny_overhead);
    let path = std::env::var("BENCH_PROFILE_OVERHEAD_JSON")
        .unwrap_or_else(|_| "BENCH_profile_overhead.json".to_string());
    std::fs::write(&path, out.render() + "\n").expect("write bench json");
    println!("wrote {path}");
    println!("profile_overhead: OK ({:.1}% on real kernels)", overhead * 100.0);
}
