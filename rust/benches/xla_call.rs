//! XLA-runtime benches: artifact execution latency (the §5.4 "optimized
//! library" path) vs the native rust kernels for the same computation,
//! plus transformer train-step throughput per preset.

use rustflow::runtime::{artifact_dir, load_artifact};
use rustflow::util::rng::Pcg32;
use rustflow::util::stats;
use rustflow::xla_model::{TransformerConfig, XlaTrainer};
use rustflow::Tensor;

fn main() {
    let relu = artifact_dir().join("relu_layer.hlo.txt");
    if !relu.exists() {
        eprintln!("artifacts missing — run `make artifacts` first; skipping xla benches");
        return;
    }
    // relu(x·w+b): XLA artifact vs native kernels.
    {
        // Artifacts exist, but a default (no `--features xla`) build has
        // only the stub bridge — skip rather than panic.
        let exe = match load_artifact(&relu) {
            Ok(exe) => exe,
            Err(e) => {
                eprintln!("XLA runtime unavailable ({e}); skipping xla benches");
                return;
            }
        };
        let (m, k, n) = (32usize, 64usize, 128usize);
        let mut rng = Pcg32::new(5);
        let x = Tensor::from_f32(vec![m, k], (0..m * k).map(|_| rng.normal()).collect()).unwrap();
        let w =
            Tensor::from_f32(vec![k, n], (0..k * n).map(|_| rng.normal() * 0.1).collect()).unwrap();
        let b = Tensor::from_f32(vec![n], (0..n).map(|_| rng.normal() * 0.1).collect()).unwrap();
        let s = stats::bench(10, 300, || {
            exe.run(&[x.clone(), w.clone(), b.clone()]).unwrap();
        });
        stats::report("xla/relu_layer_32x64x128", &s);
        let s = stats::bench(10, 300, || {
            let mm = rustflow::kernels::matrix::matmul(&x, &w, false, false).unwrap();
            let pre = rustflow::kernels::nn::bias_add(&mm, &b).unwrap();
            rustflow::kernels::nn::relu(&pre).unwrap();
        });
        stats::report("native/relu_layer_32x64x128", &s);
    }
    // Transformer train step per preset.
    for preset in ["tiny", "small"] {
        match TransformerConfig::preset(preset) {
            Ok(cfg) => {
                let mut trainer = match XlaTrainer::new(&artifact_dir(), &cfg, 1) {
                    Ok(t) => t,
                    Err(e) => {
                        eprintln!("preset {preset} unavailable ({e}); skipped");
                        continue;
                    }
                };
                trainer.train_step().unwrap(); // compile warmup
                let s = stats::bench(2, 15, || {
                    trainer.train_step().unwrap();
                });
                let toks = (cfg.batch * cfg.seq_len) as f64;
                stats::report_throughput(
                    &format!("xla/transformer_{preset}_step ({} params)", cfg.num_params()),
                    &s,
                    toks,
                    "tokens",
                );
            }
            Err(_) => eprintln!("preset {preset} artifact missing; skipped"),
        }
    }
}
