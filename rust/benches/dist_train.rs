//! Data-parallel distributed training bench (§4.4 + §5.5): steps/sec at
//! 1/2/4 in-process replicas driving one parameter-server shard over
//! loopback TCP, and bytes-on-wire with vs without bf16 gradient/param
//! compression at fixed work.
//!
//! Acceptance bar: compression cuts total wire traffic by ≥ 40% while the
//! run still converges (final loss below the uncompressed run's bar).
//!
//!     cargo bench --bench dist_train
//!
//! Writes BENCH_dist_train.json (path from $BENCH_DIST_TRAIN_JSON, set by
//! scripts/bench.sh).

use rustflow::data;
use rustflow::distributed::{DistTrainer, DistTrainerOptions, ParamServer, PsOptions};
use rustflow::models;
use rustflow::optim::Optimizer;
use rustflow::util::json::Json;
use rustflow::{DType, GraphBuilder, SessionOptions};
use std::time::{Duration, Instant};

const DIM: usize = 16;
const HIDDEN: usize = 32;
const CLASSES: usize = 4;
const BATCH: usize = 16;

struct RunOut {
    updates_per_sec: f64,
    wire_bytes: u64,
    first_loss: f32,
    last_loss: f32,
    elapsed: Duration,
}

/// Train `replicas` closed-loop replica threads for `steps` each against a
/// fresh in-process shard; returns throughput, traffic, and the loss arc.
fn run(mode: &str, replicas: usize, steps: usize, compress: bool) -> RunOut {
    let ps = ParamServer::new(PsOptions {
        opt: Optimizer::sgd(0.1),
        sync_replicas: (mode == "sync").then_some(replicas),
        ..Default::default()
    });
    let addr = ps.serve("127.0.0.1:0").unwrap().to_string();
    let examples = data::synthetic_classification(replicas * BATCH * 4, DIM, CLASSES, 0.3, 5);

    let t0 = Instant::now();
    let losses: Vec<Vec<f32>> = std::thread::scope(|scope| {
        let handles: Vec<_> = (0..replicas)
            .map(|r| {
                let addr = addr.clone();
                let examples = &examples;
                scope.spawn(move || {
                    let mut b = GraphBuilder::new();
                    let x = b.placeholder("x", DType::F32).unwrap();
                    let labels = b.placeholder("labels", DType::F32).unwrap();
                    let (logits, vars) =
                        models::mlp(&mut b, x, &[DIM, HIDDEN, CLASSES], 11).unwrap();
                    let loss = models::xent_loss(&mut b, logits, labels).unwrap();
                    let mut t = DistTrainer::new(
                        b,
                        loss,
                        &vars,
                        r as u32,
                        &[addr],
                        DistTrainerOptions { compress, ..Default::default() },
                        SessionOptions::default(),
                    )
                    .unwrap();
                    t.init_params().unwrap();
                    let shards = replicas * 4;
                    (0..steps)
                        .map(|s| {
                            let shard = (r * 4 + s % 4) % shards;
                            let batch = &examples[shard * BATCH..(shard + 1) * BATCH];
                            let (f, l) = data::batch_tensors(batch).unwrap();
                            let one_hot = data::one_hot(l.as_i32().unwrap(), CLASSES);
                            t.step(&[("x", f), ("labels", one_hot)]).unwrap()
                        })
                        .collect::<Vec<f32>>()
                })
            })
            .collect();
        handles.into_iter().map(|h| h.join().expect("replica thread panicked")).collect()
    });
    let elapsed = t0.elapsed();
    let wire_bytes = ps.wire_bytes();
    ps.shutdown();

    let updates = if mode == "sync" { steps } else { steps * replicas };
    RunOut {
        updates_per_sec: updates as f64 / elapsed.as_secs_f64(),
        wire_bytes,
        first_loss: losses[0][0],
        last_loss: losses[0][steps - 1],
        elapsed,
    }
}

fn main() {
    println!(
        "{:<34} {:>10} {:>12} {:>10} {:>10}",
        "config", "updates/s", "wire KiB", "loss[0]", "loss[-1]"
    );
    let mut out = Json::obj()
        .set("bench", "dist_train")
        .set("model", format!("mlp {DIM}x{HIDDEN}x{CLASSES}"))
        .set("batch", BATCH);

    // Throughput: asynchronous (Downpour) scaling over replica count.
    let mut scaling = Json::arr();
    for replicas in [1usize, 2, 4] {
        let r = run("async", replicas, 50, true);
        println!(
            "{:<34} {:>10.1} {:>12.1} {:>10.4} {:>10.4}",
            format!("async replicas={replicas} (compressed)"),
            r.updates_per_sec,
            r.wire_bytes as f64 / 1024.0,
            r.first_loss,
            r.last_loss,
        );
        scaling.push(
            Json::obj()
                .set("replicas", replicas)
                .set("updates_per_sec", r.updates_per_sec)
                .set("wire_bytes", r.wire_bytes)
                .set("elapsed_ms", r.elapsed.as_millis() as u64)
                .set("first_loss", r.first_loss as f64)
                .set("last_loss", r.last_loss as f64),
        );
    }
    out = out.set("async_scaling", scaling);

    // Bytes-on-wire: identical synchronous work, compression off vs on.
    // Sync mode makes the two runs step-for-step comparable.
    let (replicas, steps) = (2usize, 60usize);
    let plain = run("sync", replicas, steps, false);
    let packed = run("sync", replicas, steps, true);
    for (label, r) in [("uncompressed", &plain), ("bf16-compressed", &packed)] {
        println!(
            "{:<34} {:>10.1} {:>12.1} {:>10.4} {:>10.4}",
            format!("sync replicas={replicas} ({label})"),
            r.updates_per_sec,
            r.wire_bytes as f64 / 1024.0,
            r.first_loss,
            r.last_loss,
        );
    }
    let reduction = 1.0 - packed.wire_bytes as f64 / plain.wire_bytes as f64;
    // "Unchanged convergence": both runs improve, and the compressed run
    // lands in the uncompressed run's neighborhood.
    let converged = plain.last_loss < plain.first_loss
        && packed.last_loss < packed.first_loss
        && packed.last_loss <= plain.last_loss * 1.25 + 0.05;
    println!(
        "compression: {:.1}% fewer bytes on the wire, convergence {}",
        reduction * 100.0,
        if converged { "unchanged" } else { "DEGRADED" },
    );
    out = out.set(
        "compression",
        Json::obj()
            .set("sync_replicas", replicas)
            .set("steps", steps)
            .set("bytes_uncompressed", plain.wire_bytes)
            .set("bytes_compressed", packed.wire_bytes)
            .set("reduction", reduction)
            .set("loss_uncompressed", plain.last_loss as f64)
            .set("loss_compressed", packed.last_loss as f64)
            .set("converged", converged),
    );

    let path = std::env::var("BENCH_DIST_TRAIN_JSON")
        .unwrap_or_else(|_| "BENCH_dist_train.json".to_string());
    std::fs::write(&path, out.render()).expect("write bench json");
    println!("\nwrote {path}");

    assert!(
        reduction >= 0.40,
        "bf16 compression must cut wire traffic by >= 40% (got {:.1}%)",
        reduction * 100.0
    );
    assert!(converged, "compressed run failed to match uncompressed convergence");
    println!("dist_train: OK (wire bytes -{:.1}%, convergence unchanged)", reduction * 100.0);
}
