//! Placement benches: §3.2.1 greedy simulation cost vs graph size and
//! device count, and placement quality (estimated makespan).

use rustflow::device::DeviceSet;
use rustflow::placement::{place, CostModel};
use rustflow::util::rng::Pcg32;
use rustflow::util::stats;
use rustflow::{GraphBuilder, Tensor};

fn random_graph(nodes: usize, seed: u64) -> GraphBuilder {
    let mut rng = Pcg32::new(seed);
    let mut b = GraphBuilder::new();
    let mut pool = vec![b.constant(Tensor::fill_f32(vec![32, 32], 0.1))];
    for _ in 0..nodes {
        let a = pool[rng.index(pool.len())];
        let c = pool[rng.index(pool.len())];
        let v = match rng.next_below(3) {
            0 => b.matmul(a, c),
            1 => b.add(a, c),
            _ => b.tanh(a),
        };
        pool.push(v);
    }
    b
}

fn main() {
    for (nodes, devices) in [(100usize, 2usize), (100, 8), (1000, 2), (1000, 8)] {
        let ds = DeviceSet::local(devices, 1);
        let cm = CostModel::new();
        let s = stats::bench(2, 20, || {
            let mut b = random_graph(nodes, 7);
            place(&mut b.graph, &ds, &cm).unwrap();
        });
        stats::report_throughput(
            &format!("placement/{nodes}nodes_{devices}dev"),
            &s,
            nodes as f64,
            "nodes",
        );
    }
    // Quality: makespan of greedy placement vs all-on-one-device.
    {
        let mut b = random_graph(300, 11);
        let cm = CostModel::new();
        let ds4 = DeviceSet::local(4, 1);
        let stats4 = place(&mut b.graph, &ds4, &cm).unwrap();
        let mut b1 = random_graph(300, 11);
        let ds1 = DeviceSet::local(1, 1);
        let stats1 = place(&mut b1.graph, &ds1, &cm).unwrap();
        println!(
            "placement/quality: est. makespan 1 dev {:.0}us vs 4 dev {:.0}us ({:.2}x)",
            stats1.estimated_makespan_us,
            stats4.estimated_makespan_us,
            stats1.estimated_makespan_us / stats4.estimated_makespan_us
        );
    }
}
