//! Serving throughput: requests/sec vs. dynamic-batch size at several
//! client concurrencies. The acceptance-criterion row is batch=32 vs
//! batch=1 at 8 concurrent clients — batching amortizes the per-step
//! overhead (dispatch, executor wakeup) over every row in the batch, so
//! throughput should rise with max_batch_size while per-request latency
//! stays bounded by max_batch_delay.
//!
//!     cargo bench --bench serving

use rustflow::serving::{BatchConfig, ModelServer};
use rustflow::util::stats::Summary;
use rustflow::{models, DType, GraphBuilder, Session, SessionOptions, Tensor};
use std::sync::Arc;
use std::time::{Duration, Instant};

fn main() {
    // Small per-row compute so the fixed per-step overhead (dispatch,
    // executor wakeup) is the dominant cost — the thing batching
    // amortizes. Scale dims up to see the compute-bound regime instead.
    let (dim, hidden, classes) = (32usize, 128usize, 10usize);
    let per_client = 200usize;

    let mut b = GraphBuilder::new();
    let x = b.placeholder("x", DType::F32).unwrap();
    let (logits, _vars) = models::mlp(&mut b, x, &[dim, hidden, classes], 7).unwrap();
    let fetch = format!("{}:0", b.graph.node(logits.node).name);
    let inits: Vec<String> =
        b.init_ops.iter().map(|&i| b.graph.node(i).name.clone()).collect();
    let session = Arc::new(Session::new(
        b.into_graph(),
        // intra_op_threads: a formed batch is one large step — let its
        // MatMul row panels fan out across the device's compute pool.
        SessionOptions { threads_per_device: 4, intra_op_threads: 4, ..Default::default() },
    ));
    session
        .run_targets(&inits.iter().map(|s| s.as_str()).collect::<Vec<_>>())
        .unwrap();

    println!(
        "{:<40} {:>12} {:>12} {:>12} {:>10}",
        "config", "req/s", "p50", "p95", "mean batch"
    );
    for clients in [8usize, 16] {
        for max_batch in [1usize, 8, 32] {
            let config = BatchConfig {
                max_batch_size: max_batch,
                max_batch_delay: Duration::from_millis(2),
                queue_capacity: 4096,
                ..BatchConfig::default()
            };
            let server = Arc::new(ModelServer::with_session(Arc::clone(&session), config));
            // Warmup: compile the cached step and settle the lane thread.
            server
                .run(&[("x", Tensor::fill_f32(vec![1, dim], 0.5))], &[&fetch])
                .unwrap();

            let start = Instant::now();
            let mut handles = Vec::new();
            for c in 0..clients {
                let server = Arc::clone(&server);
                let fetch = fetch.clone();
                handles.push(std::thread::spawn(move || {
                    let mut latencies = Vec::with_capacity(per_client);
                    for i in 0..per_client {
                        let v = ((c + 1) * (i + 1) % 13) as f32 * 0.1;
                        let input = Tensor::fill_f32(vec![1, dim], v);
                        let t = Instant::now();
                        let out = server.run(&[("x", input)], &[&fetch]).unwrap();
                        latencies.push(t.elapsed());
                        std::hint::black_box(out);
                    }
                    latencies
                }));
            }
            let mut all = Vec::with_capacity(clients * per_client);
            for h in handles {
                all.extend(h.join().expect("client thread panicked"));
            }
            let elapsed = start.elapsed();
            let summary = Summary::from_samples(all);
            let rps = (clients * per_client) as f64 / elapsed.as_secs_f64();
            let stats = server.stats();
            println!(
                "{:<40} {:>12.0} {:>12?} {:>12?} {:>10.1}",
                format!("clients={clients} max_batch={max_batch}"),
                rps,
                summary.p50,
                summary.p95,
                stats.mean_batch_rows()
            );
            server.shutdown();
        }
        println!();
    }
}
