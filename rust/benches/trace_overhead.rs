//! Tracing overhead bench (§9.2 EEG): steps/sec through the same fused
//! matmul/bias/tanh stack with `SessionOptions::trace` off vs on, plus a
//! many-tiny-ops stack where per-span recording cost is most visible.
//!
//! Acceptance bar: the traced run on real kernels stays within 25% of the
//! untraced run. The untraced path is a branch on an `Option` per node,
//! so its cost is bounded above by the full trace-on overhead — keeping
//! that small certifies the production (trace-off) path is unaffected.
//!
//!     cargo bench --bench trace_overhead
//!
//! Writes BENCH_trace_overhead.json (path from $BENCH_TRACE_OVERHEAD_JSON,
//! set by scripts/bench.sh).

use rustflow::util::json::Json;
use rustflow::util::stats;
use rustflow::{GraphBuilder, Session, SessionOptions, Tensor};
use std::time::Duration;

fn filled(r: usize, c: usize, seed: u32) -> Tensor {
    let v: Vec<f32> = (0..r * c)
        .map(|i| {
            let h = (i as u32).wrapping_mul(2654435761).wrapping_add(seed);
            ((h % 1000) as f32) * 0.002 - 1.0
        })
        .collect();
    Tensor::from_f32(vec![r, c], v).unwrap()
}

/// Steps/sec (and the fetched output for bit-identity checks) through a
/// `depth`-layer matmul/bias/tanh stack at width `dim`.
fn stack_steps_per_sec(dim: usize, depth: u32, trace: bool) -> (f64, Tensor) {
    let mut b = GraphBuilder::new();
    let x = b.placeholder("x", rustflow::DType::F32).unwrap();
    let mut h = x;
    for l in 0..depth {
        let w = b.constant(filled(dim, dim, 100 + l));
        let bias = b.constant(filled(1, dim, 200 + l));
        let mm = b.matmul(h, w);
        let s = b.add(mm, bias);
        h = b.tanh(s);
    }
    let fetch = format!("{}:0", b.graph.node(h.node).name);
    // profile_window: 0 keeps the continuous profiler (which implies
    // per-step tracing) out of both arms — this bench isolates the trace
    // flag itself; benches/profile_overhead.rs measures the profiler.
    let sess = Session::new(
        b.into_graph(),
        SessionOptions { trace, profile_window: 0, ..Default::default() },
    );
    let feed = filled(dim, dim, 7);
    let run = || sess.run(&[("x", feed.clone())], &[&fetch], &[]).unwrap().remove(0);
    let out = run(); // warm: compile + fill arena pool
    let s = stats::bench_for(3, Duration::from_secs(2), || {
        run();
    });
    if trace {
        let t = sess.last_trace().expect("tracing enabled");
        assert!(!t.events().is_empty(), "traced run recorded no spans");
    }
    (1.0 / s.mean.as_secs_f64(), out)
}

fn main() {
    // Real kernels: 6 layers of 256x256 matmul/bias/tanh. Span recording
    // is amortized over ~180 MFLOP/step, so this is the production shape.
    let (off, out_off) = stack_steps_per_sec(256, 6, false);
    let (on, out_on) = stack_steps_per_sec(256, 6, true);
    assert_eq!(
        out_off.as_f32().unwrap(),
        out_on.as_f32().unwrap(),
        "tracing must not change results"
    );
    let overhead = off / on - 1.0;
    println!(
        "trace_overhead/stack 6x256: {off:.1} steps/s off, {on:.1} steps/s on \
         ({:.1}% overhead)",
        overhead * 100.0
    );

    // Worst case: 48 layers of 16x16 — hundreds of tiny kernels per step,
    // so the per-span clock reads dominate. Reported, not asserted.
    let (tiny_off, _) = stack_steps_per_sec(16, 48, false);
    let (tiny_on, _) = stack_steps_per_sec(16, 48, true);
    let tiny_overhead = tiny_off / tiny_on - 1.0;
    println!(
        "trace_overhead/tiny 48x16: {tiny_off:.1} steps/s off, {tiny_on:.1} steps/s on \
         ({:.1}% overhead)",
        tiny_overhead * 100.0
    );

    assert!(
        overhead <= 0.25,
        "tracing overhead on real kernels must stay within 25%, got {:.1}%",
        overhead * 100.0
    );

    let out = Json::obj()
        .set("bench", "trace_overhead")
        .set("stack_steps_per_sec_off", off)
        .set("stack_steps_per_sec_on", on)
        .set("stack_overhead", overhead)
        .set("tiny_steps_per_sec_off", tiny_off)
        .set("tiny_steps_per_sec_on", tiny_on)
        .set("tiny_overhead", tiny_overhead);
    let path = std::env::var("BENCH_TRACE_OVERHEAD_JSON")
        .unwrap_or_else(|_| "BENCH_trace_overhead.json".to_string());
    std::fs::write(&path, out.render() + "\n").expect("write bench json");
    println!("wrote {path}");
    println!("trace_overhead: OK ({:.1}% on real kernels)", overhead * 100.0);
}
