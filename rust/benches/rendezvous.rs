//! Rendezvous benches: local handoff latency and throughput (§3.2.2's
//! data path), plus abort cost.

use rustflow::rendezvous::{recv_blocking, LocalRendezvous, Rendezvous};
use rustflow::util::stats;
use rustflow::Tensor;
use std::sync::Arc;

fn main() {
    // send-then-recv same thread.
    {
        let r = LocalRendezvous::new();
        let t = Tensor::scalar_f32(1.0);
        let mut i = 0u64;
        let s = stats::bench(1000, 100_000, || {
            let key = format!("k{i}");
            r.send(&key, t.clone()).unwrap();
            recv_blocking(&*r, &key).unwrap();
            i += 1;
        });
        stats::report("rendezvous/send_recv_same_thread", &s);
    }
    // Cross-thread pipeline throughput.
    {
        let r = LocalRendezvous::new();
        let n = 50_000u64;
        let t0 = std::time::Instant::now();
        let r2 = Arc::clone(&r);
        let producer = std::thread::spawn(move || {
            let t = Tensor::fill_f32(vec![64], 0.5);
            for i in 0..n {
                r2.send(&format!("x{i}"), t.clone()).unwrap();
            }
        });
        for i in 0..n {
            recv_blocking(&*r, &format!("x{i}")).unwrap();
        }
        producer.join().unwrap();
        let dt = t0.elapsed();
        println!(
            "rendezvous/cross_thread_pipeline                 {:>14.0} tensors/s",
            n as f64 / dt.as_secs_f64()
        );
    }
    // recv-before-send (callback parking) cost.
    {
        let r = LocalRendezvous::new();
        let t = Tensor::scalar_f32(1.0);
        let mut i = 0u64;
        let s = stats::bench(1000, 100_000, || {
            let key = format!("p{i}");
            let (tx, rx) = std::sync::mpsc::channel();
            r.recv_async(&key, Box::new(move |res| {
                let _ = tx.send(res);
            }));
            r.send(&key, t.clone()).unwrap();
            rx.recv().unwrap().unwrap();
            i += 1;
        });
        stats::report("rendezvous/recv_then_send_parked", &s);
    }
}
