//! §5.5 compression benches: codec bandwidth and the wire-size effect.

use rustflow::compress::{bf16_to_f32, f32_to_bf16};
use rustflow::tensor::codec;
use rustflow::util::stats;
use rustflow::Tensor;

fn main() {
    for n in [1024usize, 1 << 16, 1 << 20] {
        let t = Tensor::from_f32(vec![n], (0..n).map(|i| (i as f32).sin()).collect()).unwrap();
        let s = stats::bench(3, 50, || {
            let c = f32_to_bf16(&t).unwrap();
            std::hint::black_box(&c);
        });
        stats::report_throughput(
            &format!("compress/f32_to_bf16_{n}"),
            &s,
            (n * 4) as f64 / 1e6,
            "MB",
        );
        let c = f32_to_bf16(&t).unwrap();
        let s = stats::bench(3, 50, || {
            let d = bf16_to_f32(&c).unwrap();
            std::hint::black_box(&d);
        });
        stats::report_throughput(
            &format!("compress/bf16_to_f32_{n}"),
            &s,
            (n * 2) as f64 / 1e6,
            "MB",
        );
        println!(
            "compress/wire_bytes_{n}: f32 {} -> bf16 {} ({:.2}x)",
            codec::encode(&t).len(),
            codec::encode(&c).len(),
            codec::encode(&t).len() as f64 / codec::encode(&c).len() as f64
        );
    }
}
