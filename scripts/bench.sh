#!/usr/bin/env bash
# Perf trajectory, as one command: runs the §5 optimizer ablation bench and
# the serving throughput bench, and writes BENCH_optimizer.json at the repo
# root (machine-readable; one file per tracked benchmark family).
#
#   scripts/bench.sh
#
# The optimizer bench also asserts the acceptance bar (full pipeline
# ≥ 1.3x over passes-disabled), so this script fails on a perf regression.
set -eu
cd "$(dirname "$0")/.."

export BENCH_OPTIMIZER_JSON="$(pwd)/BENCH_optimizer.json"

echo "== cargo bench --bench optimizer (writes $BENCH_OPTIMIZER_JSON)"
cargo bench --bench optimizer

echo "== cargo bench --bench serving"
cargo bench --bench serving

echo "bench: OK"
