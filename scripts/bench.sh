#!/usr/bin/env bash
# Perf trajectory, as one command: runs the §5 optimizer ablation bench,
# the step-memory-planner bench, and the serving throughput bench, and
# writes BENCH_optimizer.json + BENCH_memory.json at the repo root
# (machine-readable; one file per tracked benchmark family).
#
#   scripts/bench.sh
#
# The optimizer bench asserts its acceptance bar (full pipeline ≥ 1.3x
# over passes-disabled) and the memory bench asserts planning-on
# allocates ≥ 2x fewer heap bytes per step than planning-off, so this
# script fails on a perf regression.
set -eu
cd "$(dirname "$0")/.."

export BENCH_OPTIMIZER_JSON="$(pwd)/BENCH_optimizer.json"
export BENCH_MEMORY_JSON="$(pwd)/BENCH_memory.json"

echo "== cargo bench --bench optimizer (writes $BENCH_OPTIMIZER_JSON)"
cargo bench --bench optimizer

echo "== cargo bench --bench memory (writes $BENCH_MEMORY_JSON)"
cargo bench --bench memory

echo "== cargo bench --bench serving"
cargo bench --bench serving

echo "bench: OK"
