#!/usr/bin/env bash
# Perf trajectory, as one command: runs the §5 optimizer ablation bench,
# the step-memory-planner bench, the intra-op parallelism bench, the
# serving throughput bench, the wire-serving (model hub) bench, the
# distributed-training bench, the tracing-overhead bench, and the sparse
# embedding bench, and writes BENCH_optimizer.json + BENCH_memory.json +
# BENCH_parallel.json + BENCH_serving_net.json + BENCH_dist_train.json +
# BENCH_trace_overhead.json + BENCH_embeddings.json at the repo root
# (machine-readable; one file per tracked benchmark family).
#
#   scripts/bench.sh
#
# The optimizer bench asserts its acceptance bar (full pipeline ≥ 1.3x
# over passes-disabled), the memory bench asserts planning-on allocates
# ≥ 2x fewer heap bytes per step than planning-off, the parallel bench
# asserts the packed GEMM gives ≥ 2x the old blocked kernel and the
# im2col conv ≥ 3x the direct loop at 4 intra-op threads (≥ 4 cores)
# with no 1-thread regression, the serving_net bench
# asserts a mid-run model hot-swap costs < 20% of one throughput window
# (≥ 4 cores), the dist_train bench asserts bf16 gradient/param
# compression cuts wire bytes ≥ 40% at unchanged convergence, the
# trace_overhead bench asserts step tracing costs ≤ 25% on real kernels,
# the embeddings bench asserts the native IndexedSlices wire path
# sustains ≥ 2x dense steps/s at ≤ 10% touched rows, and the
# profile_overhead bench asserts the always-on continuous profiler costs
# ≤ 10% on real kernels, so this script fails on a perf regression.
set -eu
cd "$(dirname "$0")/.."

export BENCH_OPTIMIZER_JSON="$(pwd)/BENCH_optimizer.json"
export BENCH_MEMORY_JSON="$(pwd)/BENCH_memory.json"
export BENCH_PARALLEL_JSON="$(pwd)/BENCH_parallel.json"
export BENCH_SERVING_NET_JSON="$(pwd)/BENCH_serving_net.json"
export BENCH_DIST_TRAIN_JSON="$(pwd)/BENCH_dist_train.json"
export BENCH_TRACE_OVERHEAD_JSON="$(pwd)/BENCH_trace_overhead.json"
export BENCH_EMBEDDINGS_JSON="$(pwd)/BENCH_embeddings.json"
export BENCH_PROFILE_OVERHEAD_JSON="$(pwd)/BENCH_profile_overhead.json"

echo "== cargo bench --bench optimizer (writes $BENCH_OPTIMIZER_JSON)"
cargo bench --bench optimizer

echo "== cargo bench --bench memory (writes $BENCH_MEMORY_JSON)"
cargo bench --bench memory

echo "== cargo bench --bench parallel (writes $BENCH_PARALLEL_JSON)"
cargo bench --bench parallel

echo "== cargo bench --bench serving"
cargo bench --bench serving

echo "== cargo bench --bench serving_net (writes $BENCH_SERVING_NET_JSON)"
cargo bench --bench serving_net

echo "== cargo bench --bench dist_train (writes $BENCH_DIST_TRAIN_JSON)"
cargo bench --bench dist_train

echo "== cargo bench --bench trace_overhead (writes $BENCH_TRACE_OVERHEAD_JSON)"
cargo bench --bench trace_overhead

echo "== cargo bench --bench embeddings (writes $BENCH_EMBEDDINGS_JSON)"
cargo bench --bench embeddings

echo "== cargo bench --bench profile_overhead (writes $BENCH_PROFILE_OVERHEAD_JSON)"
cargo bench --bench profile_overhead

echo "bench: OK"
