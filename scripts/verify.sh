#!/usr/bin/env bash
# Tier-1 verification gate, as one command:
#
#   scripts/verify.sh
#
# Gates, in order: cargo fmt --check, cargo clippy -D warnings, then the
# ROADMAP tier-1 pair `cargo build --release && cargo test -q`. fmt/clippy
# are skipped (with a notice) when the component is not installed —
# offline toolchains often carry neither — but fail the script when they
# are present and unhappy.
set -u
cd "$(dirname "$0")/.."

if cargo fmt --version >/dev/null 2>&1; then
  echo "== cargo fmt --check"
  cargo fmt --all -- --check || exit 1
else
  echo "== cargo fmt --check (skipped: rustfmt not installed)"
fi

if cargo clippy --version >/dev/null 2>&1; then
  echo "== cargo clippy -D warnings"
  cargo clippy --workspace --all-targets -- -D warnings || exit 1
else
  echo "== cargo clippy (skipped: clippy not installed)"
fi

echo "== cargo build --release"
cargo build --release || exit 1

echo "== cargo test -q"
cargo test -q || exit 1

echo "verify: OK"
