#!/usr/bin/env bash
# Tier-1 verification gate, as one command:
#
#   scripts/verify.sh            # fmt + clippy advisory, build + test gating
#   STRICT=1 scripts/verify.sh   # fmt + clippy also gate
#
# `cargo build --release && cargo test -q` is the hard gate (ROADMAP
# "Tier-1 verify"). fmt/clippy run first and report, but only fail the
# script under STRICT=1, and are skipped when the component is not
# installed (offline toolchains often carry neither).
set -u
cd "$(dirname "$0")/.."

soft_fail=0

if cargo fmt --version >/dev/null 2>&1; then
  echo "== cargo fmt --check"
  if ! cargo fmt --all -- --check; then
    echo "fmt: NOT CLEAN"
    soft_fail=1
  fi
else
  echo "== cargo fmt --check (skipped: rustfmt not installed)"
fi

if cargo clippy --version >/dev/null 2>&1; then
  echo "== cargo clippy"
  if ! cargo clippy --workspace --all-targets; then
    echo "clippy: FAILED"
    soft_fail=1
  fi
else
  echo "== cargo clippy (skipped: clippy not installed)"
fi

echo "== cargo build --release"
cargo build --release || exit 1

echo "== cargo test -q"
cargo test -q || exit 1

if [ "${STRICT:-0}" != "0" ] && [ "$soft_fail" != "0" ]; then
  echo "verify: build+test passed but fmt/clippy failed under STRICT=1"
  exit 1
fi
echo "verify: OK"
