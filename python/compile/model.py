"""L2: the transformer LM train step in JAX, calling the L1 Pallas kernels.

This is the "Inception port" analog for the reproduction: the model math
lives here, is lowered ONCE by aot.py to HLO text, and executes inside the
rust coordinator via PJRT (`XlaCall`). Python is never on the training
path.

The step is fully fused: forward, loss, backward, and SGD update in one
program — `(tokens, *params) -> (loss, *new_params)`.
"""

from dataclasses import dataclass, field

import jax
import jax.numpy as jnp

from .kernels import fused, ref


@dataclass(frozen=True)
class Config:
    name: str = "tiny"
    vocab: int = 128
    d_model: int = 64
    n_layers: int = 2
    n_heads: int = 2
    d_ff: int = 256
    seq_len: int = 32
    batch: int = 8
    lr: float = 0.1
    use_pallas: bool = True

    @property
    def d_head(self):
        return self.d_model // self.n_heads


PRESETS = {
    "tiny": Config(),
    "small": Config(name="small", vocab=512, d_model=128, n_layers=4, n_heads=4,
                    d_ff=512, seq_len=64, batch=8, lr=0.05),
    "base": Config(name="base", vocab=2048, d_model=256, n_layers=8, n_heads=8,
                   d_ff=1024, seq_len=128, batch=8, lr=0.02),
    "100m": Config(name="100m", vocab=8192, d_model=768, n_layers=12, n_heads=12,
                   d_ff=3072, seq_len=128, batch=4, lr=0.01),
}


def param_spec(cfg: Config):
    """Ordered (name, shape, init) list — the rust/python contract.

    init ∈ {normal, zeros, ones}; rust initializes from this spec (the
    init *distribution* need not match flax conventions, only be sane).
    """
    spec = [
        ("tok_emb", (cfg.vocab, cfg.d_model), "normal"),
        ("pos_emb", (cfg.seq_len, cfg.d_model), "normal"),
    ]
    for l in range(cfg.n_layers):
        spec += [
            (f"l{l}.ln1_scale", (cfg.d_model,), "ones"),
            (f"l{l}.ln1_bias", (cfg.d_model,), "zeros"),
            (f"l{l}.wqkv", (cfg.d_model, 3 * cfg.d_model), "normal"),
            (f"l{l}.wo", (cfg.d_model, cfg.d_model), "normal"),
            (f"l{l}.ln2_scale", (cfg.d_model,), "ones"),
            (f"l{l}.ln2_bias", (cfg.d_model,), "zeros"),
            (f"l{l}.w1", (cfg.d_model, cfg.d_ff), "normal"),
            (f"l{l}.b1", (cfg.d_ff,), "zeros"),
            (f"l{l}.w2", (cfg.d_ff, cfg.d_model), "normal"),
            (f"l{l}.b2", (cfg.d_model,), "zeros"),
        ]
    spec += [
        ("lnf_scale", (cfg.d_model,), "ones"),
        ("lnf_bias", (cfg.d_model,), "zeros"),
    ]
    return spec


def num_params(cfg: Config):
    return sum(int(jnp.prod(jnp.array(s))) for _, s, _ in param_spec(cfg))


def init_params(cfg: Config, seed=0):
    key = jax.random.PRNGKey(seed)
    params = []
    for name, shape, init in param_spec(cfg):
        key, sub = jax.random.split(key)
        if init == "normal":
            params.append(0.02 * jax.random.normal(sub, shape, jnp.float32))
        elif init == "ones":
            params.append(jnp.ones(shape, jnp.float32))
        else:
            params.append(jnp.zeros(shape, jnp.float32))
    return params


def _mlp_block(cfg: Config, x2d, w1, b1, w2, b2):
    if cfg.use_pallas:
        h = fused.matmul_bias_act(x2d, w1, b1, act="relu")
        return fused.matmul_bias_act(h, w2, b2, act="none")
    h = ref.matmul_bias_act(x2d, w1, b1, act="relu")
    return ref.matmul_bias_act(h, w2, b2, act="none")


def _attention(cfg: Config, q, k, v):
    if cfg.use_pallas:
        return fused.mha_causal(q, k, v)
    return jax.vmap(jax.vmap(ref.causal_attention))(q, k, v)


def forward(cfg: Config, params, tokens):
    """tokens [B, S] int32 -> logits [B, S, V]."""
    b, s = tokens.shape
    it = iter(params)
    tok_emb = next(it)
    pos_emb = next(it)
    x = tok_emb[tokens] + pos_emb[None, :s, :]
    for _ in range(cfg.n_layers):
        ln1_s, ln1_b = next(it), next(it)
        wqkv, wo = next(it), next(it)
        ln2_s, ln2_b = next(it), next(it)
        w1, b1, w2, b2 = next(it), next(it), next(it), next(it)
        # attention block (pre-LN)
        h = ref.layer_norm(x, ln1_s, ln1_b)
        qkv = h.reshape(b * s, cfg.d_model) @ wqkv
        qkv = qkv.reshape(b, s, 3, cfg.n_heads, cfg.d_head)
        q = qkv[:, :, 0].transpose(0, 2, 1, 3)  # [B, H, S, hd]
        k = qkv[:, :, 1].transpose(0, 2, 1, 3)
        v = qkv[:, :, 2].transpose(0, 2, 1, 3)
        att = _attention(cfg, q, k, v)  # [B, H, S, hd]
        att = att.transpose(0, 2, 1, 3).reshape(b * s, cfg.d_model)
        x = x + (att @ wo).reshape(b, s, cfg.d_model)
        # mlp block
        h = ref.layer_norm(x, ln2_s, ln2_b).reshape(b * s, cfg.d_model)
        x = x + _mlp_block(cfg, h, w1, b1, w2, b2).reshape(b, s, cfg.d_model)
    lnf_s, lnf_b = next(it), next(it)
    x = ref.layer_norm(x, lnf_s, lnf_b)
    # untied would add V*D params; tie with the embedding instead.
    return x @ params[0].T


def loss_fn(cfg: Config, params, tokens_with_target):
    """tokens [B, S+1] -> mean next-token cross entropy."""
    inputs = tokens_with_target[:, :-1]
    targets = tokens_with_target[:, 1:]
    logits = forward(cfg, params, inputs)
    logp = jax.nn.log_softmax(logits, axis=-1)
    nll = -jnp.take_along_axis(logp, targets[..., None], axis=-1)
    return nll.mean()


def train_step(cfg: Config, tokens_with_target, *params):
    """One fused SGD step: (loss, *new_params)."""
    loss, grads = jax.value_and_grad(lambda ps: loss_fn(cfg, ps, tokens_with_target))(
        list(params)
    )
    if cfg.use_pallas:
        new = [fused.sgd_update(p, g, cfg.lr) for p, g in zip(params, grads)]
    else:
        new = [ref.sgd_update(p, g, cfg.lr) for p, g in zip(params, grads)]
    return (loss, *new)


def relu_layer(x, w, b):
    """The Fig-1 hot spot as a standalone artifact: relu(x @ w + b) via the
    L1 kernel — used by the rust XlaCall unit tests and benches."""
    return (fused.matmul_bias_act(x, w, b, act="relu"),)
