"""L1 Pallas kernels: the compute hot-spots of the L2 transformer.

All kernels run with ``interpret=True`` — the CPU PJRT plugin cannot
execute Mosaic custom-calls, so interpret mode lowers them to plain HLO
that the rust runtime executes. The *structure* is still written for the
TPU roofline (DESIGN.md §Hardware-Adaptation):

* ``matmul_bias_act`` tiles M×N output blocks sized for the 128×128 MXU,
  streaming K through VMEM-resident blocks (BlockSpec expresses the
  HBM↔VMEM schedule the paper's GPU kernels did with threadblocks);
* ``causal_attention`` keeps one (head, query-block) tile resident and
  walks key blocks, the standard VMEM-budget decomposition;
* ``sgd_update`` is a bandwidth-bound elementwise tile loop.

VMEM/MXU estimates for these block shapes are recorded in EXPERIMENTS.md
§Perf (interpret-mode wallclock is NOT a TPU proxy).
"""

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _ceil_div(a, b):
    return (a + b - 1) // b


# ---------------------------------------------------------------------------
# fused matmul + bias + activation
# ---------------------------------------------------------------------------


_BM, _BN, _BK = 128, 128, 128  # MXU-shaped tiles


def _matmul_bias_act_pallas(x, w, b, act="none"):
    """relu-or-identity(x @ w + b) as a tiled Pallas kernel.

    Shapes must tile evenly by the block size or be smaller than one
    block; the L2 model picks dimensions accordingly (power-of-two model
    dims), and the pytest sweep covers ragged fallback via the reference.
    """
    m, k = x.shape
    k2, n = w.shape
    assert k == k2 and b.shape == (n,)
    bm, bn = min(_BM, m), min(_BN, n)
    if m % bm or n % bn:
        # Ragged shapes: fall back to one whole-array kernel invocation.
        bm, bn = m, n

    # Each grid cell owns one (bm, bn) output tile; BlockSpec streams the
    # matching x-rows and w-columns (full K) into VMEM. K fits VMEM for
    # every model dimension we build (see §Perf VMEM accounting); a K-loop
    # with pl.dslice would extend this to unbounded K.
    def kernel(x_ref, w_ref, b_ref, o_ref):
        out = x_ref[...] @ w_ref[...] + b_ref[...]
        if act == "relu":
            out = jnp.maximum(out, 0.0)
        o_ref[...] = out

    return pl.pallas_call(
        kernel,
        grid=(m // bm, n // bn),
        in_specs=[
            pl.BlockSpec((bm, k), lambda i, j: (i, 0)),
            pl.BlockSpec((k, bn), lambda i, j: (0, j)),
            pl.BlockSpec((bn,), lambda i, j: (j,)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), jnp.float32),
        interpret=True,
    )(x, w, b)


# Reverse-mode support: pallas_call has no automatic VJP, so the kernel
# carries a custom one. The backward matmuls reuse the pallas kernel
# itself (zero bias, no activation) — backward is tiled for the MXU too.
import functools


@functools.partial(jax.custom_vjp, nondiff_argnums=(3,))
def matmul_bias_act(x, w, b, act="none"):
    return _matmul_bias_act_pallas(x, w, b, act)


def _mba_fwd(x, w, b, act):
    y = _matmul_bias_act_pallas(x, w, b, act)
    return y, (x, w, y)


def _mba_bwd(act, res, dy):
    x, w, y = res
    if act == "relu":
        dy = dy * (y > 0).astype(dy.dtype)
    zero_k = jnp.zeros((x.shape[1],), dy.dtype)
    zero_n = jnp.zeros((w.shape[1],), dy.dtype)
    dx = _matmul_bias_act_pallas(dy, w.T, zero_k, "none")
    dw = _matmul_bias_act_pallas(x.T, dy, zero_n, "none")
    db = dy.sum(axis=0)
    return dx, dw, db


matmul_bias_act.defvjp(_mba_fwd, _mba_bwd)


# ---------------------------------------------------------------------------
# causal attention (single head; L2 vmaps over batch × heads)
# ---------------------------------------------------------------------------


def _causal_attention_pallas(q, k, v):
    """softmax(q kᵀ / √d + causal mask) v for one head. q,k,v: [S, d]."""
    s, d = q.shape
    scale = 1.0 / (d ** 0.5)

    def kernel(q_ref, k_ref, v_ref, o_ref):
        qs = q_ref[...]
        ks = k_ref[...]
        vs = v_ref[...]
        scores = (qs @ ks.T) * scale
        ids = jax.lax.broadcasted_iota(jnp.int32, (s, s), 0)
        jds = jax.lax.broadcasted_iota(jnp.int32, (s, s), 1)
        scores = jnp.where(ids >= jds, scores, jnp.finfo(jnp.float32).min)
        m = scores.max(axis=-1, keepdims=True)
        e = jnp.exp(scores - m)
        p = e / e.sum(axis=-1, keepdims=True)
        o_ref[...] = p @ vs

    return pl.pallas_call(
        kernel,
        out_shape=jax.ShapeDtypeStruct((s, d), jnp.float32),
        interpret=True,
    )(q, k, v)


@jax.custom_vjp
def causal_attention(q, k, v):
    return _causal_attention_pallas(q, k, v)


def _attn_fwd(q, k, v):
    return _causal_attention_pallas(q, k, v), (q, k, v)


def _attn_bwd(res, dy):
    # Backward through the mathematically-identical reference (the pallas
    # forward matches ref to float tolerance, validated by pytest).
    from . import ref

    q, k, v = res
    _, vjp = jax.vjp(ref.causal_attention, q, k, v)
    return vjp(dy)


causal_attention.defvjp(_attn_fwd, _attn_bwd)


def mha_causal(q, k, v):
    """Multi-head wrapper: q,k,v [B, H, S, d] -> [B, H, S, d]."""
    return jax.vmap(jax.vmap(causal_attention))(q, k, v)


# ---------------------------------------------------------------------------
# SGD parameter update
# ---------------------------------------------------------------------------


def sgd_update(p, g, lr):
    """p - lr * g as an elementwise Pallas kernel (any shape)."""
    flat = p.reshape(-1)
    gflat = g.reshape(-1)

    def kernel(p_ref, g_ref, o_ref):
        o_ref[...] = p_ref[...] - lr * g_ref[...]

    out = pl.pallas_call(
        kernel,
        out_shape=jax.ShapeDtypeStruct(flat.shape, flat.dtype),
        interpret=True,
    )(flat, gflat)
    return out.reshape(p.shape)
