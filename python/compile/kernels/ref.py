"""Pure-jnp oracles for the Pallas kernels (L1 correctness ground truth).

Every Pallas kernel in this package has an exact reference here; pytest
(`python/tests/`) sweeps shapes with hypothesis and asserts allclose.
"""

import jax
import jax.numpy as jnp


def matmul_bias_act(x, w, b, act="none"):
    """relu-or-identity(x @ w + b)."""
    y = x @ w + b
    if act == "relu":
        y = jnp.maximum(y, 0.0)
    elif act != "none":
        raise ValueError(f"unknown act {act!r}")
    return y


def causal_attention(q, k, v):
    """Single-head causal attention. q, k, v: [S, d] -> [S, d]."""
    s, d = q.shape
    scale = 1.0 / jnp.sqrt(jnp.asarray(d, dtype=q.dtype))
    scores = (q @ k.T) * scale
    mask = jnp.tril(jnp.ones((s, s), dtype=bool))
    scores = jnp.where(mask, scores, jnp.finfo(q.dtype).min)
    p = jax.nn.softmax(scores, axis=-1)
    return p @ v


def sgd_update(p, g, lr):
    """p - lr * g."""
    return p - lr * g


def layer_norm(x, scale, bias, eps=1e-5):
    mu = x.mean(axis=-1, keepdims=True)
    var = ((x - mu) ** 2).mean(axis=-1, keepdims=True)
    return (x - mu) / jnp.sqrt(var + eps) * scale + bias
