"""AOT compiler: lower the L2 programs to HLO *text* artifacts for the
rust runtime. Run once via `make artifacts`; never on the training path.

HLO text (NOT `.serialize()`): jax >= 0.5 emits protos with 64-bit
instruction ids which xla_extension 0.5.1 rejects; the text parser
reassigns ids (see /opt/xla-example/README.md).
"""

import argparse
import os

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from . import model


def to_hlo_text(lowered) -> str:
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def write_meta(path, cfg, spec):
    """Plain-text artifact metadata: the rust/python contract."""
    lines = [
        f"name={cfg.name}",
        f"vocab={cfg.vocab}",
        f"d_model={cfg.d_model}",
        f"n_layers={cfg.n_layers}",
        f"n_heads={cfg.n_heads}",
        f"d_ff={cfg.d_ff}",
        f"seq_len={cfg.seq_len}",
        f"batch={cfg.batch}",
        f"lr={cfg.lr}",
    ]
    for name, shape, init in spec:
        dims = ",".join(str(d) for d in shape)
        lines.append(f"param {name} {dims} {init}")
    with open(path, "w") as f:
        f.write("\n".join(lines) + "\n")


def build_transformer(out_dir, preset):
    cfg = model.PRESETS[preset]
    spec = model.param_spec(cfg)
    tokens = jax.ShapeDtypeStruct((cfg.batch, cfg.seq_len + 1), jnp.int32)
    params = [jax.ShapeDtypeStruct(s, jnp.float32) for _, s, _ in spec]

    def step(tokens, *params):
        return model.train_step(cfg, tokens, *params)

    lowered = jax.jit(step).lower(tokens, *params)
    text = to_hlo_text(lowered)
    hlo_path = os.path.join(out_dir, f"transformer_{preset}.hlo.txt")
    with open(hlo_path, "w") as f:
        f.write(text)
    write_meta(os.path.join(out_dir, f"transformer_{preset}.meta.txt"), cfg, spec)
    print(f"wrote {hlo_path} ({len(text)} chars, {model.num_params(cfg)} params)")


def build_relu_layer(out_dir, m=32, k=64, n=128):
    x = jax.ShapeDtypeStruct((m, k), jnp.float32)
    w = jax.ShapeDtypeStruct((k, n), jnp.float32)
    b = jax.ShapeDtypeStruct((n,), jnp.float32)
    lowered = jax.jit(model.relu_layer).lower(x, w, b)
    path = os.path.join(out_dir, "relu_layer.hlo.txt")
    with open(path, "w") as f:
        f.write(to_hlo_text(lowered))
    with open(os.path.join(out_dir, "relu_layer.meta.txt"), "w") as f:
        f.write(f"m={m}\nk={k}\nn={n}\n")
    print(f"wrote {path}")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--out-dir", default="../artifacts")
    ap.add_argument(
        "--presets",
        default="tiny,small",
        help="comma-separated transformer presets to build (tiny,small,base,100m)",
    )
    args = ap.parse_args()
    os.makedirs(args.out_dir, exist_ok=True)
    build_relu_layer(args.out_dir)
    for preset in args.presets.split(","):
        if preset:
            build_transformer(args.out_dir, preset.strip())


if __name__ == "__main__":
    main()
