"""L1 correctness: Pallas kernels vs the pure-jnp oracle (`ref.py`).

Hypothesis sweeps shapes; assert_allclose against the reference — the CORE
correctness signal for the compute layer.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import fused, ref


def rand(key, *shape):
    return jax.random.normal(jax.random.PRNGKey(key), shape, jnp.float32)


# ---------------------------------------------------------------------------
# matmul_bias_act
# ---------------------------------------------------------------------------


@settings(max_examples=8, deadline=None)
@given(
    m=st.integers(1, 32),
    k=st.integers(1, 32),
    n=st.integers(1, 32),
    act=st.sampled_from(["none", "relu"]),
)
def test_matmul_bias_act_matches_ref(m, k, n, act):
    x, w, b = rand(1, m, k), rand(2, k, n), rand(3, n)
    got = fused.matmul_bias_act(x, w, b, act=act)
    want = ref.matmul_bias_act(x, w, b, act=act)
    np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-5)


def test_matmul_bias_act_tiled_path():
    # Shapes that exercise the multi-block grid (128-divisible).
    x, w, b = rand(4, 256, 128), rand(5, 128, 256), rand(6, 256)
    got = fused.matmul_bias_act(x, w, b, act="relu")
    want = ref.matmul_bias_act(x, w, b, act="relu")
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-4)
    assert (np.asarray(got) >= 0).all()


# ---------------------------------------------------------------------------
# causal attention
# ---------------------------------------------------------------------------


@settings(max_examples=6, deadline=None)
@given(s=st.integers(2, 24), d=st.integers(2, 16))
def test_attention_matches_ref(s, d):
    q, k, v = rand(7, s, d), rand(8, s, d), rand(9, s, d)
    got = fused.causal_attention(q, k, v)
    want = ref.causal_attention(q, k, v)
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-5)


def test_attention_is_causal():
    # Output at position i must not depend on keys/values after i.
    s, d = 8, 4
    q, k, v = rand(10, s, d), rand(11, s, d), rand(12, s, d)
    base = fused.causal_attention(q, k, v)
    k2 = k.at[-1].set(99.0)
    v2 = v.at[-1].set(-99.0)
    perturbed = fused.causal_attention(q, k2, v2)
    np.testing.assert_allclose(base[:-1], perturbed[:-1], rtol=1e-5, atol=1e-6)


def test_mha_shape():
    b, h, s, d = 2, 3, 8, 4
    q = rand(13, b, h, s, d)
    out = fused.mha_causal(q, q, q)
    assert out.shape == (b, h, s, d)


# ---------------------------------------------------------------------------
# sgd update
# ---------------------------------------------------------------------------


@settings(max_examples=6, deadline=None)
@given(n=st.integers(1, 128), lr=st.floats(1e-4, 1.0))
def test_sgd_matches_ref(n, lr):
    p, g = rand(14, n), rand(15, n)
    got = fused.sgd_update(p, g, lr)
    want = ref.sgd_update(p, g, lr)
    np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-6)


def test_sgd_preserves_shape_2d():
    p, g = rand(16, 6, 7), rand(17, 6, 7)
    out = fused.sgd_update(p, g, 0.1)
    assert out.shape == (6, 7)
    np.testing.assert_allclose(out, p - 0.1 * g, rtol=1e-5, atol=1e-6)
