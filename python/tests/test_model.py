"""L2 correctness: transformer shapes, loss behaviour, pallas-vs-reference
model parity, and AOT lowering health."""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from compile import model
from compile.model import PRESETS, Config


def tiny():
    # Shrunk further for test speed.
    return Config(name="test", vocab=32, d_model=16, n_layers=1, n_heads=2,
                  d_ff=32, seq_len=8, batch=2, lr=0.5)


def tokens_for(cfg, seed=0):
    key = jax.random.PRNGKey(seed)
    return jax.random.randint(key, (cfg.batch, cfg.seq_len + 1), 0, cfg.vocab, jnp.int32)


def test_param_spec_consistent():
    cfg = tiny()
    spec = model.param_spec(cfg)
    params = model.init_params(cfg)
    assert len(spec) == len(params)
    for (name, shape, init), p in zip(spec, params):
        assert p.shape == shape, name
        if init == "ones":
            np.testing.assert_allclose(p, 1.0)
        if init == "zeros":
            np.testing.assert_allclose(p, 0.0)


def test_forward_shapes():
    cfg = tiny()
    params = model.init_params(cfg)
    toks = tokens_for(cfg)[:, :-1]
    logits = model.forward(cfg, params, toks)
    assert logits.shape == (cfg.batch, cfg.seq_len, cfg.vocab)
    assert np.isfinite(np.asarray(logits)).all()


def test_initial_loss_near_uniform():
    cfg = tiny()
    params = model.init_params(cfg)
    loss = model.loss_fn(cfg, params, tokens_for(cfg))
    assert abs(float(loss) - np.log(cfg.vocab)) < 0.5


def test_train_step_reduces_loss():
    cfg = tiny()
    params = model.init_params(cfg)
    toks = tokens_for(cfg)
    step = jax.jit(lambda t, *p: model.train_step(cfg, t, *p))
    first = None
    for _ in range(10):
        out = step(toks, *params)
        loss, params = out[0], list(out[1:])
        if first is None:
            first = float(loss)
    assert float(loss) < first, f"loss {first} -> {float(loss)}"


def test_pallas_and_reference_models_agree():
    cfg = tiny()
    cfg_ref = dataclasses.replace(cfg, use_pallas=False)
    params = model.init_params(cfg)
    toks = tokens_for(cfg)
    lp = model.loss_fn(cfg, params, toks)
    lr_ = model.loss_fn(cfg_ref, params, toks)
    np.testing.assert_allclose(float(lp), float(lr_), rtol=1e-4)


def test_presets_well_formed():
    for name, cfg in PRESETS.items():
        assert cfg.d_model % cfg.n_heads == 0, name
        assert model.num_params(cfg) > 0


def test_aot_lowering_tiny(tmp_path):
    from compile import aot

    aot.build_relu_layer(str(tmp_path))
    assert (tmp_path / "relu_layer.hlo.txt").read_text().startswith("HloModule")
    aot.build_transformer(str(tmp_path), "tiny")
    hlo = (tmp_path / "transformer_tiny.hlo.txt").read_text()
    assert hlo.startswith("HloModule")
    meta = (tmp_path / "transformer_tiny.meta.txt").read_text()
    assert "param tok_emb 128,64 normal" in meta
    assert "lr=0.1" in meta
