//! §4.4 / Fig 7 data-parallel distributed training over a parameter
//! server: replicas own local Sessions computing gradients, a
//! [`ParamServer`] shard owns the parameters and applies the update —
//! synchronously (averaged across replicas, bit-identical to one big
//! batch) and asynchronously (Downpour). Gradients and pulls travel
//! bf16-compressed (§5.5) where negotiated.
//!
//!     cargo run --release --example dist_train -- [replicas] [steps] [trace]
//!
//! Exits non-zero if training fails to reduce the loss (CI smoke). With a
//! literal `trace` argument, reruns a short synchronous session with step
//! tracing on everywhere (§9.2 EEG) and writes the merged replica +
//! parameter-server timeline to `dist_trace.json` (chrome://tracing).
//!
//! [`ParamServer`]: rustflow::distributed::ParamServer

use rustflow::data;
use rustflow::distributed::{DistTrainer, DistTrainerOptions, ParamServer, PsOptions};
use rustflow::models;
use rustflow::optim::Optimizer;
use rustflow::util::json::Json;
use rustflow::{DType, GraphBuilder, SessionOptions};

const DIM: usize = 16;
const CLASSES: usize = 4;
const BATCH: usize = 16;

/// A replica's local graph: placeholder-fed MLP + xent loss, gradient-only
/// (the server owns the update rule).
fn build_replica(
) -> rustflow::Result<(GraphBuilder, rustflow::Endpoint, Vec<rustflow::Endpoint>)> {
    let mut b = GraphBuilder::new();
    let x = b.placeholder("x", DType::F32)?;
    let labels = b.placeholder("labels", DType::F32)?;
    let (logits, vars) = models::mlp(&mut b, x, &[DIM, 32, CLASSES], 11)?;
    let loss = models::xent_loss(&mut b, logits, labels)?;
    Ok((b, loss, vars))
}

/// Train `replicas` threads against one in-process shard; returns
/// (first-seen loss, last loss, total wire bytes, elapsed).
fn train(
    mode: &str,
    replicas: usize,
    steps: usize,
    compress: bool,
) -> rustflow::Result<(f32, f32, u64, std::time::Duration)> {
    let ps = ParamServer::new(PsOptions {
        opt: Optimizer::sgd(0.1),
        sync_replicas: (mode == "sync").then_some(replicas),
        ..Default::default()
    });
    let addr = ps.serve("127.0.0.1:0")?.to_string();

    let examples = data::synthetic_classification(replicas * BATCH * 4, DIM, CLASSES, 0.3, 5);
    let t0 = std::time::Instant::now();
    let mut losses: Vec<Vec<f32>> = Vec::new();
    std::thread::scope(|scope| -> rustflow::Result<()> {
        let mut handles = Vec::new();
        for r in 0..replicas {
            let addr = addr.clone();
            let examples = &examples;
            handles.push(scope.spawn(move || -> rustflow::Result<Vec<f32>> {
                let (b, loss, vars) = build_replica()?;
                let mut t = DistTrainer::new(
                    b,
                    loss,
                    &vars,
                    r as u32,
                    &[addr],
                    DistTrainerOptions { compress, ..Default::default() },
                    SessionOptions::default(),
                )?;
                t.init_params()?;
                let shards = replicas * 4;
                let mut out = Vec::with_capacity(steps);
                for s in 0..steps {
                    // Round-robin over this replica's shards of the data.
                    let shard = (r * 4 + s % 4) % shards;
                    let batch = &examples[shard * BATCH..(shard + 1) * BATCH];
                    let (f, l) = data::batch_tensors(batch)?;
                    let one_hot = data::one_hot(l.as_i32()?, CLASSES);
                    out.push(t.step(&[("x", f), ("labels", one_hot)])?);
                }
                Ok(out)
            }));
        }
        for h in handles {
            losses.push(h.join().expect("replica thread panicked")?);
        }
        Ok(())
    })?;
    let dt = t0.elapsed();
    let bytes = ps.wire_bytes();
    ps.shutdown();
    let first = losses[0][0];
    let last = losses[0][losses[0].len() - 1];
    Ok((first, last, bytes, dt))
}

/// Tracing smoke: the same sync topology with `trace: true` everywhere,
/// replica fragments handed to replica 0, whose `merged_trace` pulls the
/// shard's spans and renders one clock-aligned chrome://tracing JSON.
fn trace_smoke(replicas: usize, steps: usize) -> rustflow::Result<()> {
    let ps = ParamServer::new(PsOptions {
        opt: Optimizer::sgd(0.1),
        sync_replicas: Some(replicas),
        trace: true,
        ..Default::default()
    });
    let addr = ps.serve("127.0.0.1:0")?.to_string();
    let examples = data::synthetic_classification(replicas * BATCH * 4, DIM, CLASSES, 0.3, 5);

    let trainers: Vec<DistTrainer> = std::thread::scope(|scope| {
        let handles: Vec<_> = (0..replicas)
            .map(|r| {
                let addr = addr.clone();
                let examples = &examples;
                scope.spawn(move || -> rustflow::Result<DistTrainer> {
                    let (b, loss, vars) = build_replica()?;
                    let mut t = DistTrainer::new(
                        b,
                        loss,
                        &vars,
                        r as u32,
                        &[addr],
                        DistTrainerOptions::default(),
                        SessionOptions { trace: true, ..Default::default() },
                    )?;
                    t.init_params()?;
                    let shards = replicas * 4;
                    for s in 0..steps {
                        let shard = (r * 4 + s % 4) % shards;
                        let batch = &examples[shard * BATCH..(shard + 1) * BATCH];
                        let (f, l) = data::batch_tensors(batch)?;
                        let one_hot = data::one_hot(l.as_i32()?, CLASSES);
                        t.step(&[("x", f), ("labels", one_hot)])?;
                    }
                    Ok(t)
                })
            })
            .collect();
        handles
            .into_iter()
            .map(|h| h.join().expect("replica thread panicked"))
            .collect::<rustflow::Result<Vec<_>>>()
    })?;
    // Let the applier finish recording the final apply span (pushes
    // unblock on the version bump, a hair before the span ends).
    std::thread::sleep(std::time::Duration::from_millis(100));

    let mut it = trainers.into_iter();
    let lead = it.next().expect("at least one replica");
    let frags: Vec<_> = it.filter_map(|t| t.take_trace()).collect();
    let json = lead.merged_trace(frags)?;
    ps.shutdown();

    let parsed = Json::parse(&json).expect("merged trace parses");
    let arr = parsed.as_array().expect("merged trace is an event array");
    let lane = |pid: &str| {
        arr.iter().filter(|e| e.get("pid").and_then(Json::as_str) == Some(pid)).count()
    };
    let (worker_spans, ps_spans) = (lane("replica:0"), lane("ps"));
    std::fs::write("dist_trace.json", &json).expect("write dist_trace.json");
    println!(
        "trace: {} spans ({worker_spans} on replica:0, {ps_spans} on ps) -> dist_trace.json",
        arr.len(),
    );
    if worker_spans == 0 || ps_spans == 0 {
        eprintln!("merged trace is missing a worker or ps lane");
        std::process::exit(1);
    }
    Ok(())
}

fn main() -> rustflow::Result<()> {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let replicas: usize = args.first().and_then(|s| s.parse().ok()).unwrap_or(2);
    let steps: usize = args.get(1).and_then(|s| s.parse().ok()).unwrap_or(60);
    if args.iter().any(|a| a == "trace") {
        return trace_smoke(replicas, steps.min(8));
    }

    let mut ok = true;
    for (mode, compress) in [("sync", false), ("async", true)] {
        let (first, last, bytes, dt) = train(mode, replicas, steps, compress)?;
        let updates = if mode == "sync" { steps } else { steps * replicas };
        let improved = last < first;
        ok &= improved;
        println!(
            "{mode:>5} ({}compressed): {replicas} replicas, {updates} updates in {dt:?} \
             ({:.1} updates/s), {:.1} KiB on the wire, loss {first:.4} -> {last:.4}{}",
            if compress { "" } else { "un" },
            updates as f64 / dt.as_secs_f64(),
            bytes as f64 / 1024.0,
            if improved { "" } else { "  [NO IMPROVEMENT]" },
        );
    }
    if !ok {
        eprintln!("distributed training failed to reduce the loss");
        std::process::exit(1);
    }
    Ok(())
}
