//! §4.4 / Fig 7 data-parallel distributed training over a parameter
//! server: replicas own local Sessions computing gradients, a
//! [`ParamServer`] shard owns the parameters and applies the update —
//! synchronously (averaged across replicas, bit-identical to one big
//! batch) and asynchronously (Downpour). Gradients and pulls travel
//! bf16-compressed (§5.5) where negotiated.
//!
//!     cargo run --release --example dist_train -- [replicas] [steps]
//!
//! Exits non-zero if training fails to reduce the loss (CI smoke).
//!
//! [`ParamServer`]: rustflow::distributed::ParamServer

use rustflow::data;
use rustflow::distributed::{DistTrainer, DistTrainerOptions, ParamServer, PsOptions};
use rustflow::models;
use rustflow::optim::Optimizer;
use rustflow::{DType, GraphBuilder, SessionOptions};

const DIM: usize = 16;
const CLASSES: usize = 4;
const BATCH: usize = 16;

/// A replica's local graph: placeholder-fed MLP + xent loss, gradient-only
/// (the server owns the update rule).
fn build_replica(
) -> rustflow::Result<(GraphBuilder, rustflow::Endpoint, Vec<rustflow::Endpoint>)> {
    let mut b = GraphBuilder::new();
    let x = b.placeholder("x", DType::F32)?;
    let labels = b.placeholder("labels", DType::F32)?;
    let (logits, vars) = models::mlp(&mut b, x, &[DIM, 32, CLASSES], 11)?;
    let loss = models::xent_loss(&mut b, logits, labels)?;
    Ok((b, loss, vars))
}

/// Train `replicas` threads against one in-process shard; returns
/// (first-seen loss, last loss, total wire bytes, elapsed).
fn train(
    mode: &str,
    replicas: usize,
    steps: usize,
    compress: bool,
) -> rustflow::Result<(f32, f32, u64, std::time::Duration)> {
    let ps = ParamServer::new(PsOptions {
        opt: Optimizer::sgd(0.1),
        sync_replicas: (mode == "sync").then_some(replicas),
        ..Default::default()
    });
    let addr = ps.serve("127.0.0.1:0")?.to_string();

    let examples = data::synthetic_classification(replicas * BATCH * 4, DIM, CLASSES, 0.3, 5);
    let t0 = std::time::Instant::now();
    let mut losses: Vec<Vec<f32>> = Vec::new();
    std::thread::scope(|scope| -> rustflow::Result<()> {
        let mut handles = Vec::new();
        for r in 0..replicas {
            let addr = addr.clone();
            let examples = &examples;
            handles.push(scope.spawn(move || -> rustflow::Result<Vec<f32>> {
                let (b, loss, vars) = build_replica()?;
                let mut t = DistTrainer::new(
                    b,
                    loss,
                    &vars,
                    r as u32,
                    &[addr],
                    DistTrainerOptions { compress, ..Default::default() },
                    SessionOptions::default(),
                )?;
                t.init_params()?;
                let shards = replicas * 4;
                let mut out = Vec::with_capacity(steps);
                for s in 0..steps {
                    // Round-robin over this replica's shards of the data.
                    let shard = (r * 4 + s % 4) % shards;
                    let batch = &examples[shard * BATCH..(shard + 1) * BATCH];
                    let (f, l) = data::batch_tensors(batch)?;
                    let one_hot = data::one_hot(l.as_i32()?, CLASSES);
                    out.push(t.step(&[("x", f), ("labels", one_hot)])?);
                }
                Ok(out)
            }));
        }
        for h in handles {
            losses.push(h.join().expect("replica thread panicked")?);
        }
        Ok(())
    })?;
    let dt = t0.elapsed();
    let bytes = ps.wire_bytes();
    ps.shutdown();
    let first = losses[0][0];
    let last = losses[0][losses[0].len() - 1];
    Ok((first, last, bytes, dt))
}

fn main() -> rustflow::Result<()> {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let replicas: usize = args.first().and_then(|s| s.parse().ok()).unwrap_or(2);
    let steps: usize = args.get(1).and_then(|s| s.parse().ok()).unwrap_or(60);

    let mut ok = true;
    for (mode, compress) in [("sync", false), ("async", true)] {
        let (first, last, bytes, dt) = train(mode, replicas, steps, compress)?;
        let updates = if mode == "sync" { steps } else { steps * replicas };
        let improved = last < first;
        ok &= improved;
        println!(
            "{mode:>5} ({}compressed): {replicas} replicas, {updates} updates in {dt:?} \
             ({:.1} updates/s), {:.1} KiB on the wire, loss {first:.4} -> {last:.4}{}",
            if compress { "" } else { "un" },
            updates as f64 / dt.as_secs_f64(),
            bytes as f64 / 1024.0,
            if improved { "" } else { "  [NO IMPROVEMENT]" },
        );
    }
    if !ok {
        eprintln!("distributed training failed to reduce the loss");
        std::process::exit(1);
    }
    Ok(())
}
