//! Inference serving with dynamic batching: N client threads hammer one
//! ModelServer with single-row MLP inference requests, first with
//! batching disabled (every request is its own Session step) and then
//! with the dynamic batcher coalescing concurrent requests into shared
//! steps. Prints throughput, latency percentiles, and the mean batch
//! size actually achieved.
//!
//!     cargo run --release --example serving -- [clients] [requests-per-client]

use rustflow::serving::{BatchConfig, ModelServer};
use rustflow::util::stats::Summary;
use rustflow::{models, DType, GraphBuilder, Session, SessionOptions, Tensor};
use std::sync::Arc;
use std::time::{Duration, Instant};

fn main() -> rustflow::Result<()> {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let clients: usize = args.first().and_then(|s| s.parse().ok()).unwrap_or(8);
    let per_client: usize = args.get(1).and_then(|s| s.parse().ok()).unwrap_or(250);
    let (dim, hidden, classes) = (64usize, 256usize, 10usize);

    // ---- the served model: a 2-layer MLP classifier ----------------------
    let mut b = GraphBuilder::new();
    let x = b.placeholder("x", DType::F32)?;
    let (logits, _vars) = models::mlp(&mut b, x, &[dim, hidden, classes], 7)?;
    let fetch = format!("{}:0", b.graph.node(logits.node).name);
    let inits: Vec<String> =
        b.init_ops.iter().map(|&i| b.graph.node(i).name.clone()).collect();
    let session = Arc::new(Session::new(
        b.into_graph(),
        SessionOptions { threads_per_device: 4, ..Default::default() },
    ));
    session.run_targets(&inits.iter().map(|s| s.as_str()).collect::<Vec<_>>())?;

    println!(
        "serving a {dim}->{hidden}->{classes} MLP to {clients} clients x {per_client} requests\n"
    );
    let configs = [
        ("unbatched (max_batch=1)", BatchConfig::unbatched()),
        (
            "dynamic batching (max_batch=32, delay=2ms)",
            BatchConfig {
                max_batch_size: 32,
                max_batch_delay: Duration::from_millis(2),
                queue_capacity: 4096,
                ..BatchConfig::default()
            },
        ),
    ];
    for (label, config) in configs {
        let server = Arc::new(ModelServer::with_session(Arc::clone(&session), config));
        let (rps, latency) = drive(&server, clients, per_client, dim, classes, &fetch);
        let stats = server.stats();
        println!("{label}:");
        println!("  {rps:10.0} requests/sec");
        println!(
            "  latency p50 {:?}  p95 {:?}  p99 {:?}",
            latency.p50, latency.p95, latency.p99
        );
        println!(
            "  {} requests -> {} steps (mean batch {:.1} rows)\n",
            stats.requests,
            stats.batches,
            stats.mean_batch_rows()
        );
        server.shutdown();
    }
    Ok(())
}

/// Run the client fleet; returns (requests/sec, latency summary).
fn drive(
    server: &Arc<ModelServer>,
    clients: usize,
    per_client: usize,
    dim: usize,
    classes: usize,
    fetch: &str,
) -> (f64, Summary) {
    let start = Instant::now();
    let mut handles = Vec::new();
    for c in 0..clients {
        let server = Arc::clone(server);
        let fetch = fetch.to_string();
        handles.push(std::thread::spawn(move || {
            let mut latencies = Vec::with_capacity(per_client);
            for i in 0..per_client {
                let row: Vec<f32> =
                    (0..dim).map(|j| ((c * per_client + i + j) % 17) as f32 * 0.1).collect();
                let input = Tensor::from_f32(vec![1, dim], row).unwrap();
                let t = Instant::now();
                let out = server.run(&[("x", input)], &[&fetch]).unwrap();
                latencies.push(t.elapsed());
                assert_eq!(out[0].shape().dims(), &[1, classes]);
            }
            latencies
        }));
    }
    let mut all = Vec::with_capacity(clients * per_client);
    for h in handles {
        all.extend(h.join().expect("client thread panicked"));
    }
    let elapsed = start.elapsed();
    let total = (clients * per_client) as f64;
    (total / elapsed.as_secs_f64(), Summary::from_samples(all))
}
