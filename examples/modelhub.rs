//! The model hub end to end: export two versions of an MLP classifier as
//! GraphDef + checkpoint artifacts, start the TCP serving front end, load
//! v1, hammer it from concurrent network clients, hot-swap to v2 mid
//! traffic (zero dropped or failed in-flight requests), then print
//! per-version stats with latency percentiles and demonstrate that a
//! version pinned to the retired v1 fails fast with NotFound.
//!
//!     cargo run --release --example modelhub -- [clients] [requests-per-client]

use rustflow::serving::{
    BatchConfig, ManagerOptions, ModelManager, ModelSpec, NetClient, NetServer, WarmupRequest,
};
use rustflow::{checkpoint, graph, models, DType, GraphBuilder, Session, SessionOptions, Tensor};
use std::path::{Path, PathBuf};
use std::sync::Arc;
use std::time::Duration;

const DIM: usize = 64;
const HIDDEN: usize = 256;
const CLASSES: usize = 10;

fn main() -> rustflow::Result<()> {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let clients: usize = args.first().and_then(|s| s.parse().ok()).unwrap_or(8);
    let per_client: usize = args.get(1).and_then(|s| s.parse().ok()).unwrap_or(300);

    let dir = std::env::temp_dir().join(format!("rustflow-modelhub-{}", std::process::id()));
    std::fs::create_dir_all(&dir)?;

    // ---- offline: "train" and export two model versions -------------------
    // (Different seeds stand in for two training runs; the artifacts are
    // exactly what a training job would ship: GraphDef + checkpoint.)
    let v1 = export_version(&dir, "v1", 7)?;
    let v2 = export_version(&dir, "v2", 42)?;
    println!("exported artifacts under {}", dir.display());

    // ---- serving side ------------------------------------------------------
    let manager = Arc::new(ModelManager::new(ManagerOptions {
        session: SessionOptions {
            threads_per_device: 4,
            intra_op_threads: 2,
            ..Default::default()
        },
        batch: BatchConfig {
            max_batch_size: 32,
            max_batch_delay: Duration::from_millis(2),
            queue_capacity: 4096,
            ..BatchConfig::default()
        },
    }));
    let server = NetServer::serve(Arc::clone(&manager), "127.0.0.1:0")?;
    let addr = server.addr().to_string();
    println!("model hub serving on {addr}");

    // Optional debug surface: MODELHUB_DEBUG_ADDR=127.0.0.1:18080 mounts
    // /healthz /varz /statusz /tracez next to the serving port.
    let debug = match std::env::var("MODELHUB_DEBUG_ADDR") {
        Ok(debug_addr) => {
            let dbg = NetServer::serve_debug(&manager, &debug_addr)?;
            println!("debug surface on http://{}", dbg.addr());
            Some(dbg)
        }
        Err(_) => None,
    };

    manager.deploy("mnist", 1, &v1.spec)?;
    println!("deployed mnist v1 (live: {:?})", manager.live_version("mnist"));

    // ---- traffic: concurrent TCP clients, hot-swap in the middle -----------
    let fetch = v1.fetch.clone();
    let mut handles = Vec::new();
    for c in 0..clients {
        let addr = addr.clone();
        let fetch = fetch.clone();
        handles.push(std::thread::spawn(move || -> (u64, u64) {
            let mut client = NetClient::connect(&addr).expect("connect");
            let (mut ok, mut failed) = (0u64, 0u64);
            for i in 0..per_client {
                let row: Vec<f32> =
                    (0..DIM).map(|j| ((c * per_client + i + j) % 17) as f32 * 0.1).collect();
                let input = Tensor::from_f32(vec![1, DIM], row).unwrap();
                match client.predict("mnist", None, &[("x", input)], &[&fetch]) {
                    Ok(out) => {
                        assert_eq!(out[0].shape().dims(), &[1, CLASSES]);
                        ok += 1;
                    }
                    Err(_) => failed += 1,
                }
            }
            (ok, failed)
        }));
    }

    // Let traffic build, then hot-swap: v2 loads, restores, warms, and
    // atomically replaces v1; v1 drains its in-flight batches and retires.
    std::thread::sleep(Duration::from_millis(150));
    println!("hot-swapping to v2 under load…");
    manager.deploy("mnist", 2, &v2.spec)?;
    println!("swap complete (live: {:?})", manager.live_version("mnist"));

    let (mut ok, mut failed) = (0u64, 0u64);
    for h in handles {
        let (o, f) = h.join().expect("client thread panicked");
        ok += o;
        failed += f;
    }
    println!("\n{ok} requests served, {failed} failed across the hot-swap");
    assert_eq!(failed, 0, "a hot-swap must not fail in-flight requests");

    // ---- per-version stats --------------------------------------------------
    println!("\nper-version stats:");
    println!(
        "{:<8} {:>4} {:<9} {:>9} {:>7} {:>11} {:>10} {:>10}",
        "model", "ver", "state", "requests", "errors", "mean batch", "p50", "p99"
    );
    for s in manager.stats() {
        println!(
            "{:<8} {:>4} {:<9} {:>9} {:>7} {:>11.1} {:>10.2?} {:>10.2?}",
            s.model,
            s.version,
            s.state.to_string(),
            s.requests,
            s.errors,
            s.batch.mean_batch_rows(),
            s.latency.p50,
            s.latency.p99,
        );
    }

    // ---- retired versions fail fast -----------------------------------------
    let mut client = NetClient::connect(&addr)?;
    let probe = Tensor::fill_f32(vec![1, DIM], 0.5);
    let pinned = client.predict("mnist", Some(1), &[("x", probe)], &[&fetch]);
    println!(
        "\npinned request to retired v1: {}",
        pinned.expect_err("v1 is retired; the pin must fail")
    );

    // Keep the surfaces up for external probes (CI curls the debug
    // endpoints while the example holds).
    if let Ok(secs) = std::env::var("MODELHUB_HOLD_SECS") {
        if let Ok(secs) = secs.parse::<u64>() {
            println!("holding for {secs}s…");
            std::thread::sleep(Duration::from_secs(secs));
        }
    }

    if let Some(dbg) = debug {
        dbg.shutdown();
    }
    server.shutdown();
    manager.shutdown();
    let _ = std::fs::remove_dir_all(&dir);
    Ok(())
}

struct ExportedVersion {
    spec: ModelSpec,
    fetch: String,
}

/// "Train" (initialize) one version and export its artifacts: the
/// GraphDef and a checkpoint bundle of the variables' values.
fn export_version(dir: &Path, tag: &str, seed: u64) -> rustflow::Result<ExportedVersion> {
    let mut b = GraphBuilder::new();
    let x = b.placeholder("x", DType::F32)?;
    let (logits, vars) = models::mlp(&mut b, x, &[DIM, HIDDEN, CLASSES], seed)?;
    let fetch = format!("{}:0", b.graph.node(logits.node).name);
    let inits: Vec<String> = b.init_ops.iter().map(|&i| b.graph.node(i).name.clone()).collect();
    let var_names: Vec<String> = vars.iter().map(|v| b.graph.node(v.node).name.clone()).collect();

    let graph_path: PathBuf = dir.join(format!("{tag}.graphdef"));
    graph::serde::write_graphdef(&graph_path, &b.graph)?;

    let sess = Session::new(b.into_graph(), SessionOptions::default());
    sess.run_targets(&inits.iter().map(String::as_str).collect::<Vec<_>>())?;
    let values =
        sess.run(&[], &var_names.iter().map(String::as_str).collect::<Vec<_>>(), &[])?;
    let pairs: Vec<(String, Tensor)> = var_names.into_iter().zip(values).collect();
    let checkpoint_path = dir.join(format!("{tag}.ckpt"));
    checkpoint::save_bundle(&checkpoint_path, &pairs)?;

    Ok(ExportedVersion {
        spec: ModelSpec {
            graph_path,
            checkpoint_path: Some(checkpoint_path),
            init_targets: vec![],
            warmup: vec![WarmupRequest {
                feeds: vec![("x".to_string(), Tensor::fill_f32(vec![1, DIM], 0.1))],
                fetches: vec![fetch.clone()],
            }],
        },
        fetch,
    })
}
