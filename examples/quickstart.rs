//! Quickstart: the paper's Figure 1 program, verbatim in the rust front
//! end — build `relu(W·x + b)`, create a Session, run it 10 times feeding
//! x, and fetch a cost.
//!
//!     cargo run --release --example quickstart

use rustflow::optim::Optimizer;
use rustflow::{DType, GraphBuilder, Session, SessionOptions, Tensor};

fn main() -> rustflow::Result<()> {
    // --- Figure 1, line for line -----------------------------------------
    // b = tf.Variable(tf.zeros([100]))
    // W = tf.Variable(tf.random_uniform([784,100], -1, 1))
    // x = tf.placeholder(name="x")
    // relu = tf.nn.relu(tf.matmul(W, x) + b)
    // C = [...]
    let mut g = GraphBuilder::new();
    let bias = g.variable("b", Tensor::zeros(DType::F32, vec![100, 1])?)?;
    let w = g.variable_uniform("W", vec![100, 784], -1.0, 1.0, 0)?;
    let x = g.placeholder("x", DType::F32)?;
    let wx = g.matmul(w, x);
    let pre = g.add(wx, bias);
    let relu = g.relu(pre);
    // A cost "computed as a function of Relu": mean of squares.
    let sq = g.square(relu);
    let cost = g.reduce_mean(sq, None);
    let cost_name = format!("{}:0", g.graph.node(cost.node).name);

    // Fig 5's addition: [db, dW, dx] = tf.gradients(C, [b, W, x])
    let grads = rustflow::autodiff::gradients(&mut g, cost, &[bias, w, x])?;
    println!(
        "gradient endpoints: db={:?} dW={:?} dx={:?}",
        grads[0].map(|e| g.graph.node(e.node).name.clone()),
        grads[1].map(|e| g.graph.node(e.node).name.clone()),
        grads[2].map(|e| g.graph.node(e.node).name.clone()),
    );
    // And one SGD step wired from those gradients (§7's training setup).
    let train = Optimizer::sgd(0.01).minimize(&mut g, cost, &[w, bias])?;
    let train_name = g.graph.node(train).name.clone();
    let inits: Vec<String> = g.init_ops.iter().map(|&i| g.graph.node(i).name.clone()).collect();

    println!("graph has {} nodes", g.graph.len());

    // s = tf.Session()
    let sess = Session::new(g.into_graph(), SessionOptions::default());
    sess.run_targets(&inits.iter().map(|s| s.as_str()).collect::<Vec<_>>())?;

    // for step in xrange(0, 10): result = s.run(C, feed_dict={x: input})
    let mut rng = rustflow::util::rng::Pcg32::new(7);
    for step in 0..10 {
        let input =
            Tensor::from_f32(vec![784, 1], (0..784).map(|_| rng.normal() * 0.1).collect())?;
        let result = sess.run(&[("x", input)], &[&cost_name], &[&train_name])?;
        println!("{step} {}", result[0].scalar_value_f32()?);
    }
    Ok(())
}
