//! Figure 7: synchronous vs asynchronous data-parallel training of an MLP
//! across N virtual devices — replicas compute gradients on shards,
//! combined synchronously (one averaged update) or applied asynchronously
//! (one client thread per replica).
//!
//!     cargo run --release --example data_parallel -- [replicas] [steps]

use rustflow::models;
use rustflow::optim::Optimizer;
use rustflow::replicate;
use rustflow::{data, GraphBuilder, Session, SessionOptions, Tensor};
use std::sync::Arc;

fn main() -> rustflow::Result<()> {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let replicas: usize = args.first().and_then(|s| s.parse().ok()).unwrap_or(4);
    let steps: usize = args.get(1).and_then(|s| s.parse().ok()).unwrap_or(200);
    let (dim, classes, per_replica_batch) = (32usize, 10usize, 32usize);

    for mode in ["sync", "async"] {
        let mut b = GraphBuilder::new();
        // Shared variables on device 0 (§7: "many replicas … collaborating
        // to update a set of shared parameters").
        let (vars, tower_losses) = build_replicated_model(
            &mut b,
            replicas,
            dim,
            classes,
            per_replica_batch,
        )?;
        let inits: Vec<String> =
            b.init_ops.iter().map(|&i| b.graph.node(i).name.clone()).collect();
        let opt = Optimizer::sgd(0.1);

        let t0 = std::time::Instant::now();
        let final_loss = match mode {
            "sync" => {
                let train = replicate::sync_data_parallel(&mut b, &vars, &tower_losses, &opt)?;
                let tname = b.graph.node(train).name.clone();
                let lname = format!("{}:0", b.graph.node(tower_losses[0].node).name);
                let sess = Session::new(
                    b.into_graph(),
                    SessionOptions { devices: replicas, ..Default::default() },
                );
                sess.run_targets(&inits.iter().map(|s| s.as_str()).collect::<Vec<_>>())?;
                let mut loss = f32::NAN;
                for _ in 0..steps {
                    loss = sess.run(&[], &[&lname], &[&tname])?[0].scalar_value_f32()?;
                }
                loss
            }
            _ => {
                let trains =
                    replicate::async_data_parallel(&mut b, &vars, &tower_losses, &opt)?;
                let tnames: Vec<String> =
                    trains.iter().map(|&t| b.graph.node(t).name.clone()).collect();
                let lname = format!("{}:0", b.graph.node(tower_losses[0].node).name);
                let sess = Arc::new(Session::new(
                    b.into_graph(),
                    SessionOptions { devices: replicas, ..Default::default() },
                ));
                sess.run_targets(&inits.iter().map(|s| s.as_str()).collect::<Vec<_>>())?;
                // One client thread per replica (Fig 7 bottom).
                std::thread::scope(|scope| {
                    for name in &tnames {
                        let sess = Arc::clone(&sess);
                        scope.spawn(move || {
                            for _ in 0..steps {
                                sess.run_targets(&[name]).unwrap();
                            }
                        });
                    }
                });
                sess.run(&[], &[&lname], &[])?[0].scalar_value_f32()?
            }
        };
        let dt = t0.elapsed();
        let total_steps = if mode == "sync" { steps } else { steps * replicas };
        println!(
            "{mode:>5}: {replicas} replicas, {total_steps} updates in {dt:?} \
             ({:.1} updates/s), final tower loss {final_loss:.4}",
            total_steps as f64 / dt.as_secs_f64()
        );
    }
    Ok(())
}

fn build_replicated_model(
    b: &mut GraphBuilder,
    replicas: usize,
    dim: usize,
    classes: usize,
    batch: usize,
) -> rustflow::Result<(Vec<rustflow::Endpoint>, Vec<rustflow::Endpoint>)> {
    let vars = b.with_device("/device:cpu:0", |b| -> rustflow::Result<_> {
        // Dummy input only to create the shared variables; towers re-read them.
        let x = b.constant(Tensor::zeros(rustflow::DType::F32, vec![1, dim])?);
        let (_, vars) = models::mlp(b, x, &[dim, 64, classes], 11)?;
        Ok(vars)
    })?;
    // Each tower gets its own data shard as constants.
    let examples = data::synthetic_classification(replicas * batch, dim, classes, 0.3, 5);
    let losses = replicate::build_towers(
        b,
        replicas,
        |i| format!("/device:cpu:{i}"),
        |b, i| {
            let shard = &examples[i * batch..(i + 1) * batch];
            let (f, l) = data::batch_tensors(shard)?;
            let x = b.constant(f);
            let labels = b.constant(data::one_hot(l.as_i32()?, classes));
            // Rebuild the forward pass reading the SHARED variables.
            let mut h = x;
            let n_layers = vars.len() / 2;
            for li in 0..n_layers {
                let mm = b.matmul(h, vars[2 * li]);
                let pre = b.bias_add(mm, vars[2 * li + 1]);
                h = if li + 1 < n_layers { b.relu(pre) } else { pre };
            }
            models::xent_loss(b, h, labels)
        },
    )?;
    Ok((vars, losses))
}
