//! Figure 3 (right): the distributed structure — a master process driving
//! worker processes over TCP, with §3.3 fault tolerance: periodic health
//! checks, checkpointing, and restart-recovery.
//!
//! Run everything in one command (spawns worker subprocesses):
//!     cargo run --release --example distributed
//! Or run roles manually:
//!     cargo run --release --example distributed -- worker 0 127.0.0.1:4400 127.0.0.1:4401
//!     cargo run --release --example distributed -- worker 1 127.0.0.1:4400 127.0.0.1:4401
//!     cargo run --release --example distributed -- master 127.0.0.1:4400 127.0.0.1:4401

use rustflow::distributed::{ClusterSpec, DistMaster, DistMasterOptions, Worker, WorkerOptions};
use rustflow::graph::AttrValue;
use rustflow::optim::Optimizer;
use rustflow::{models, GraphBuilder, Tensor};

fn main() -> rustflow::Result<()> {
    let args: Vec<String> = std::env::args().skip(1).collect();
    match args.first().map(|s| s.as_str()) {
        Some("worker") => {
            let task: usize = args[1].parse().unwrap();
            let addrs: Vec<String> = args[2..].to_vec();
            let cluster = ClusterSpec::new(addrs.clone(), 1);
            // Remote partitions parallelize large kernels too: size the
            // per-device intra-op pools (mirror of SessionOptions).
            let w = Worker::with_options(task, cluster, WorkerOptions::default());
            w.serve(&addrs[task])?;
            println!("worker {task} serving on {}", addrs[task]);
            loop {
                std::thread::sleep(std::time::Duration::from_secs(3600));
            }
        }
        Some("master") => {
            let addrs: Vec<String> = args[1..].to_vec();
            run_master(ClusterSpec::new(addrs, 1))
        }
        _ => {
            // Self-contained demo: spawn two worker subprocesses, then act
            // as master; kill worker 1 mid-training and recover.
            let exe = std::env::current_exe().unwrap();
            let ports = [pick_port(), pick_port()];
            let addrs: Vec<String> = ports.iter().map(|p| format!("127.0.0.1:{p}")).collect();
            let mut children: Vec<std::process::Child> = (0..2)
                .map(|t| {
                    std::process::Command::new(&exe)
                        .arg("worker")
                        .arg(t.to_string())
                        .args(&addrs)
                        .spawn()
                        .expect("spawn worker")
                })
                .collect();
            let cluster = ClusterSpec::new(addrs.clone(), 1);
            wait_healthy(&cluster, 50);
            let result = run_master_with_failure(cluster, &mut children, &exe, &addrs);
            for mut c in children {
                let _ = c.kill();
                let _ = c.wait();
            }
            result
        }
    }
}

fn build_graph() -> (GraphBuilder, Names) {
    let mut b = GraphBuilder::new();
    let ckpt = std::env::temp_dir()
        .join(format!("rustflow-dist-example-{}.ckpt", std::process::id()))
        .to_string_lossy()
        .to_string();
    // Parameters on worker 0, loss computation on worker 1 (Fig 3: devices
    // spread over processes).
    let (x, vars) = b.with_device("/job:worker/task:0", |b| {
        let x = b.constant(Tensor::fill_f32(vec![16, 8], 0.25));
        let (_, vars) = models::mlp(b, x, &[8, 16, 4], 21).unwrap();
        (x, vars)
    });
    let _ = x;
    let loss = b.with_device("/job:worker/task:1", |b| {
        // Pull the logits across the wire and compute a pseudo-loss.
        let mut h = b.constant(Tensor::fill_f32(vec![16, 8], 0.25));
        for li in 0..vars.len() / 2 {
            let mm = b.matmul(h, vars[2 * li]);
            let pre = b.bias_add(mm, vars[2 * li + 1]);
            h = if li + 1 < vars.len() / 2 { b.relu(pre) } else { pre };
        }
        let sq = b.square(h);
        b.reduce_mean(sq, None)
    });
    let train = Optimizer::sgd(0.05).minimize(&mut b, loss, &vars).unwrap();
    // Checkpoint plumbing (§3.3).
    let var_names: Vec<String> =
        vars.iter().map(|v| b.graph.node(v.node).name.clone()).collect();
    let save = b
        .op(
            "Save",
            "save",
            vars.clone(),
            vec![
                ("tensor_names", AttrValue::ListStr(var_names.clone())),
                ("path", AttrValue::Str(ckpt.clone())),
            ],
        )
        .unwrap();
    let restore = b
        .op(
            "Restore",
            "restore",
            vec![],
            vec![
                ("tensor_names", AttrValue::ListStr(var_names.clone())),
                (
                    "out_types",
                    AttrValue::ListType(vec![rustflow::DType::F32; vars.len()]),
                ),
                ("path", AttrValue::Str(ckpt)),
            ],
        )
        .unwrap();
    let restore_assigns: Vec<_> = vars
        .iter()
        .enumerate()
        .map(|(i, &v)| {
            b.assign(v, rustflow::Endpoint::new(restore, i)).unwrap()
        })
        .collect();
    let restore_all = b.group("restore_all", restore_assigns);
    let names = Names {
        loss: format!("{}:0", b.graph.node(loss.node).name),
        train: b.graph.node(train).name.clone(),
        save: b.graph.node(save).name.clone(),
        restore: b.graph.node(restore_all).name.clone(),
        inits: b.init_ops.iter().map(|&i| b.graph.node(i).name.clone()).collect(),
    };
    (b, names)
}

struct Names {
    loss: String,
    train: String,
    save: String,
    restore: String,
    inits: Vec<String>,
}

fn run_master(cluster: ClusterSpec) -> rustflow::Result<()> {
    let (b, names) = build_graph();
    let master = DistMaster::new(cluster, b.into_graph(), DistMasterOptions::default());
    master.health_check()?;
    master.run_targets(&names.inits.iter().map(|s| s.as_str()).collect::<Vec<_>>())?;
    for step in 0..20 {
        let out = master.run(&[], &[&names.loss], &[&names.train])?;
        if step % 5 == 0 {
            println!("step {step}: loss {}", out[0].scalar_value_f32()?);
        }
    }
    master.run_targets(&[&names.save])?;
    println!("checkpoint saved; done");
    Ok(())
}

fn run_master_with_failure(
    cluster: ClusterSpec,
    children: &mut [std::process::Child],
    exe: &std::path::Path,
    addrs: &[String],
) -> rustflow::Result<()> {
    let (b, names) = build_graph();
    let master = DistMaster::new(cluster.clone(), b.into_graph(), DistMasterOptions::default());
    master.health_check()?;
    println!("cluster healthy: {} workers", cluster.num_tasks());
    master.run_targets(&names.inits.iter().map(|s| s.as_str()).collect::<Vec<_>>())?;

    for step in 0..10 {
        let out = master.run(&[], &[&names.loss], &[&names.train])?;
        println!("step {step}: loss {:.5}", out[0].scalar_value_f32()?);
    }
    master.run_targets(&[&names.save])?;
    println!("checkpointed at step 10");

    // §3.3 failure injection: kill worker 1.
    children[1].kill().ok();
    children[1].wait().ok();
    std::thread::sleep(std::time::Duration::from_millis(100));
    match master.health_check() {
        Err(e) => println!("health check detected failure: {e}"),
        Ok(()) => println!("WARNING: failure not detected"),
    }
    let err = master.run(&[], &[&names.loss], &[&names.train]);
    println!("step during failure: {:?}", err.err().map(|e| e.code));

    // Restart the worker ("the entire graph execution is aborted and
    // restarted from scratch" — variables recover from the checkpoint).
    children[1] = std::process::Command::new(exe)
        .arg("worker")
        .arg("1")
        .args(addrs)
        .spawn()
        .expect("respawn worker");
    wait_healthy(&cluster, 50);
    master.invalidate(); // handles on the restarted worker are gone
    master.health_check()?;
    println!("worker restarted; restoring from checkpoint");
    master.run_targets(&[&names.restore])?;
    for step in 10..15 {
        let out = master.run(&[], &[&names.loss], &[&names.train])?;
        println!("step {step}: loss {:.5} (post-recovery)", out[0].scalar_value_f32()?);
    }
    println!("fault-tolerance demo complete");
    Ok(())
}

fn pick_port() -> u16 {
    std::net::TcpListener::bind("127.0.0.1:0").unwrap().local_addr().unwrap().port()
}

/// Poll until every worker answers health checks (subprocess startup —
/// loading libxla_extension takes a moment).
fn wait_healthy(cluster: &ClusterSpec, tries: usize) {
    let probe = DistMaster::new(
        cluster.clone(),
        GraphBuilder::new().into_graph(),
        DistMasterOptions::default(),
    );
    for _ in 0..tries {
        if probe.health_check().is_ok() {
            return;
        }
        std::thread::sleep(std::time::Duration::from_millis(100));
    }
}
