//! Sparse embedding subsystem end to end (§3's embedding idiom, §4.2's
//! sparse gradients): a mod-sharded table whose lookup differentiates
//! into per-shard `IndexedSlices`, sampled-softmax skip-gram training,
//! and two synchronous replicas shipping `GradEntry::Sparse` natively —
//! the dense `[vocab, dim]` gradient never exists anywhere.
//!
//!     cargo run --release --example embeddings -- [steps]
//!
//! Exits non-zero if training fails to reduce the loss or the sparse
//! wire path fails to beat the dense one (CI smoke).

use rustflow::autodiff::gradients;
use rustflow::distributed::{DistTrainer, DistTrainerOptions, ParamServer, PsOptions};
use rustflow::optim::Optimizer;
use rustflow::sparse::{self, ShardedTable};
use rustflow::tensor::Tensor;
use rustflow::util::rng::Pcg32;
use rustflow::{DType, GraphBuilder, SessionOptions};

const VOCAB: usize = 256;
const DIM: usize = 16;
const BATCH: usize = 8;
const NUM_SAMPLED: i64 = 8;
const REPLICAS: usize = 2;

fn random_table(vocab: usize, dim: usize, scale: f32, seed: u64) -> Tensor {
    let mut rng = Pcg32::new(seed);
    let v: Vec<f32> = (0..vocab * dim).map(|_| scale * rng.normal()).collect();
    Tensor::from_f32(vec![vocab, dim], v).expect("table shape")
}

/// Part 1: a 4-way mod-sharded table. The lookup is bit-identical to an
/// unsharded Gather, and its gradient is one IndexedSlices per shard —
/// indexed by *local* rows, sized by the batch, not the vocabulary.
fn sharded_demo() -> rustflow::Result<()> {
    let mut b = GraphBuilder::new();
    let t = ShardedTable::new(&mut b, "emb", random_table(64, 8, 1.0, 3), 4)?;
    let ids = sparse::ids_const(&mut b, vec![7, 41, 2, 7, 63, 12]);
    let rows = t.lookup(&mut b, ids)?;
    let sq = b.square(rows);
    let loss = b.reduce_sum(sq, None);
    let grads = gradients(&mut b, loss, &t.shards)?;
    print!("sharded: 64x8 table over {} shards; lookup of 6 ids; grads:", t.shards.len());
    for g in grads.iter() {
        let g = g.expect("every shard on the gradient path");
        let s = sparse::as_sparse(&b, g).expect("shard gradient is IndexedSlices");
        assert_ne!(s.indices, s.values);
        print!(" sparse");
    }
    println!(" (no dense [vocab, dim] tensor exists)");
    Ok(())
}

/// A replica's graph: skip-gram with sampled softmax. Both trainable
/// tensors get sparse gradients — the input table through `Gather`, the
/// output weights through `SampledSoftmax` (rows = labels + negatives).
fn build_replica(
    seed: i64,
) -> rustflow::Result<(GraphBuilder, rustflow::Endpoint, Vec<rustflow::Endpoint>)> {
    let mut b = GraphBuilder::new();
    let emb = b.variable("emb", random_table(VOCAB, DIM, 0.1, 5))?;
    let w = b.variable("w", random_table(VOCAB, DIM, 0.1, 6))?;
    let centers = b.placeholder("centers", DType::I64)?;
    let labels = b.placeholder("labels", DType::I64)?;
    let rows = b.op1("Gather", "center_emb", vec![emb, centers], vec![])?;
    let loss_vec = sparse::sampled_softmax(&mut b, rows, w, labels, NUM_SAMPLED, seed)?;
    let loss = b.reduce_sum(loss_vec, None);
    Ok((b, loss, vec![emb, w]))
}

/// Synthetic skip-gram batch: centers drawn from a fixed stream, context
/// is the next token on a ring.
fn batch(rng: &mut Pcg32) -> (Tensor, Tensor) {
    let centers: Vec<i64> = (0..BATCH).map(|_| rng.index(VOCAB) as i64).collect();
    let labels: Vec<i64> = centers.iter().map(|&c| (c + 1) % VOCAB as i64).collect();
    (
        Tensor::from_i64(vec![BATCH], centers).expect("batch shape"),
        Tensor::from_i64(vec![BATCH], labels).expect("batch shape"),
    )
}

/// Part 2+3: two synchronous replicas against one parameter-server
/// shard; embedding gradients travel as `GradEntry::Sparse` when
/// `native_sparse` is on, as fetched-dense tensors when off.
fn train(steps: usize, native_sparse: bool) -> rustflow::Result<(f32, f32, u64)> {
    let ps = ParamServer::new(PsOptions {
        opt: Optimizer::sgd(0.1),
        sync_replicas: Some(REPLICAS),
        ..Default::default()
    });
    let addr = ps.serve("127.0.0.1:0")?.to_string();
    let losses: Vec<Vec<f32>> = std::thread::scope(|scope| {
        let handles: Vec<_> = (0..REPLICAS)
            .map(|r| {
                let addr = addr.clone();
                scope.spawn(move || -> rustflow::Result<Vec<f32>> {
                    let (b, loss, vars) = build_replica(17)?;
                    let mut t = DistTrainer::new(
                        b,
                        loss,
                        &vars,
                        r as u32,
                        &[addr],
                        DistTrainerOptions {
                            compress: false,
                            native_sparse,
                            ..Default::default()
                        },
                        SessionOptions::default(),
                    )?;
                    assert_eq!(
                        t.native_sparse().iter().filter(|&&s| s).count(),
                        if native_sparse { 2 } else { 0 },
                        "emb and w both ride the IndexedSlices path iff enabled"
                    );
                    t.init_params()?;
                    let mut rng = Pcg32::new(900 + r as u64);
                    (0..steps)
                        .map(|_| {
                            let (c, l) = batch(&mut rng);
                            t.step(&[("centers", c), ("labels", l)])
                        })
                        .collect()
                })
            })
            .collect();
        handles
            .into_iter()
            .map(|h| h.join().expect("replica thread panicked"))
            .collect::<rustflow::Result<Vec<_>>>()
    })?;
    let bytes = ps.wire_bytes();
    ps.shutdown();
    Ok((losses[0][0], losses[0][losses[0].len() - 1], bytes))
}

fn main() -> rustflow::Result<()> {
    let steps: usize =
        std::env::args().nth(1).and_then(|s| s.parse().ok()).unwrap_or(40);
    sharded_demo()?;

    let (first, last, sparse_bytes) = train(steps, true)?;
    let (_, _, dense_bytes) = train(steps, false)?;
    let improved = last < first;
    println!(
        "skip-gram ({VOCAB}-token vocab, {REPLICAS} sync replicas, {steps} steps): \
         loss {first:.4} -> {last:.4}{}",
        if improved { "" } else { "  [NO IMPROVEMENT]" },
    );
    println!(
        "wire: {:.1} KiB sparse vs {:.1} KiB dense ({:.1}x)",
        sparse_bytes as f64 / 1024.0,
        dense_bytes as f64 / 1024.0,
        dense_bytes as f64 / sparse_bytes as f64,
    );
    if !improved || sparse_bytes >= dense_bytes {
        eprintln!("embedding smoke failed (improved={improved}, sparse {sparse_bytes} B, dense {dense_bytes} B)");
        std::process::exit(1);
    }
    Ok(())
}
