//! Train a 2-layer MLP classifier on a synthetic MNIST-like dataset (§6's
//! "start small and scale up" CIFAR/MNIST workflow), using the §4.5/§4.6
//! input pipeline: examples are written to record files, read by a
//! RecordInput node, and prefetched through a FIFO queue so input I/O
//! overlaps compute.
//!
//!     cargo run --release --example mnist_mlp -- [steps] [batch]

use rustflow::data;
use rustflow::graph::AttrValue;
use rustflow::optim::Optimizer;
use rustflow::{DType, GraphBuilder, Session, SessionOptions, Tensor};

fn main() -> rustflow::Result<()> {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let steps: usize = args.first().and_then(|s| s.parse().ok()).unwrap_or(300);
    let batch: i64 = args.get(1).and_then(|s| s.parse().ok()).unwrap_or(64);
    let (dim, classes, hidden) = (64usize, 10usize, 128usize);

    // ---- §4.5 input data on disk ----------------------------------------
    let dir = std::env::temp_dir().join(format!("rustflow-mnist-{}", std::process::id()));
    std::fs::create_dir_all(&dir)?;
    let file = dir.join("train.rec");
    let examples = data::synthetic_classification(4096, dim, classes, 0.35, 17);
    data::write_records(&file, &examples)?;
    println!("wrote {} examples to {}", examples.len(), file.display());

    // ---- model graph ------------------------------------------------------
    let mut g = GraphBuilder::new();
    let reader = g.op(
        "RecordInput",
        "input",
        vec![],
        vec![
            ("files", AttrValue::ListStr(vec![file.to_string_lossy().into()])),
            ("batch_size", AttrValue::I64(batch)),
        ],
    )?;
    let features = rustflow::graph::Endpoint::new(reader, 0);
    let labels_i = rustflow::graph::Endpoint::new(reader, 1);
    // One-hot labels.
    let labels64 = g.cast(labels_i, DType::I64);
    let eye = g.constant(one_hot_matrix(classes));
    let labels = g.op1("Gather", "onehot", vec![eye, labels64], vec![])?;

    let w1 = g.variable_normal("w1", vec![dim, hidden], 0.1, 1)?;
    let b1 = g.variable("b1", Tensor::zeros(DType::F32, vec![hidden])?)?;
    let w2 = g.variable_normal("w2", vec![hidden, classes], 0.1, 2)?;
    let b2 = g.variable("b2", Tensor::zeros(DType::F32, vec![classes])?)?;

    let h_pre0 = g.matmul(features, w1);
    let h_pre = g.bias_add(h_pre0, b1);
    let h = g.relu(h_pre);
    let logits0 = g.matmul(h, w2);
    let logits = g.bias_add(logits0, b2);
    let (loss_vec, _) = g.softmax_xent(logits, labels)?;
    let loss = g.reduce_mean(loss_vec, None);
    // Accuracy: argmax(logits) == label.
    let pred = g.argmax(logits, 1);
    let correct = g.equal(pred, labels64);
    let correct_f = g.cast(correct, DType::F32);
    let acc = g.reduce_mean(correct_f, None);

    let train = Optimizer::momentum(0.05, 0.9).minimize(&mut g, loss, &[w1, b1, w2, b2])?;
    let names = Names {
        loss: format!("{}:0", g.graph.node(loss.node).name),
        acc: format!("{}:0", g.graph.node(acc.node).name),
        train: g.graph.node(train).name.clone(),
        inits: g.init_ops.iter().map(|&i| g.graph.node(i).name.clone()).collect(),
    };

    let sess = Session::new(g.into_graph(), SessionOptions::default());
    sess.run_targets(&names.inits.iter().map(|s| s.as_str()).collect::<Vec<_>>())?;

    let t0 = std::time::Instant::now();
    let mut first = None;
    let mut last = (0f32, 0f32);
    for step in 0..steps {
        let out = sess.run(&[], &[&names.loss, &names.acc], &[&names.train])?;
        let (l, a) = (out[0].scalar_value_f32()?, out[1].scalar_value_f32()?);
        first.get_or_insert(l);
        last = (l, a);
        if step % 25 == 0 || step + 1 == steps {
            println!("step {step:>4}  loss {l:.4}  acc {a:.3}");
        }
    }
    let dt = t0.elapsed();
    println!(
        "trained {steps} steps in {dt:?} ({:.1} steps/s); loss {:.4} -> {:.4}, final acc {:.3}",
        steps as f64 / dt.as_secs_f64(),
        first.unwrap(),
        last.0,
        last.1
    );
    assert!(last.0 < first.unwrap(), "loss did not decrease");
    Ok(())
}

struct Names {
    loss: String,
    acc: String,
    train: String,
    inits: Vec<String>,
}

fn one_hot_matrix(n: usize) -> Tensor {
    let mut v = vec![0f32; n * n];
    for i in 0..n {
        v[i * n + i] = 1.0;
    }
    Tensor::from_f32(vec![n, n], v).unwrap()
}
