//! Figure 8: model-parallel training — a stacked LSTM whose layers are
//! pinned to different devices; the partitioner inserts the Send/Recv
//! pairs shown as dashed lines in the figure, and the per-device
//! executors pipeline timesteps.
//!
//!     cargo run --release --example model_parallel -- [layers] [seq_len]

use rustflow::models;
use rustflow::{GraphBuilder, Session, SessionOptions, Tensor};

fn main() -> rustflow::Result<()> {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let layers: usize = args.first().and_then(|s| s.parse().ok()).unwrap_or(3);
    let seq_len: usize = args.get(1).and_then(|s| s.parse().ok()).unwrap_or(16);
    let (batch, input_dim, hidden) = (8usize, 32usize, 128usize);

    for (label, devices, pin) in [
        ("single-device", 1usize, false),
        ("model-parallel", layers, true),
    ] {
        let mut b = GraphBuilder::new();
        let mut rng = rustflow::util::rng::Pcg32::new(3);
        let xs: Vec<_> = (0..seq_len)
            .map(|_| {
                b.constant(
                    Tensor::from_f32(
                        vec![batch, input_dim],
                        (0..batch * input_dim).map(|_| rng.normal() * 0.3).collect(),
                    )
                    .unwrap(),
                )
            })
            .collect();
        let device_fn = |l: usize| format!("/device:cpu:{l}");
        let (top, _vars) = models::stacked_lstm(
            &mut b,
            &xs,
            batch,
            input_dim,
            hidden,
            layers,
            if pin { Some(&device_fn) } else { None },
            9,
        )?;
        let out = b.reduce_mean(top, None);
        let oname = format!("{}:0", b.graph.node(out.node).name);
        let inits: Vec<String> =
            b.init_ops.iter().map(|&i| b.graph.node(i).name.clone()).collect();
        let sess = Session::new(
            b.into_graph(),
            SessionOptions { devices, threads_per_device: 2, ..Default::default() },
        );
        sess.run_targets(&inits.iter().map(|s| s.as_str()).collect::<Vec<_>>())?;

        // Warmup + timed steps.
        let v0 = sess.run(&[], &[&oname], &[])?[0].scalar_value_f32()?;
        let t0 = std::time::Instant::now();
        let n = 20;
        for _ in 0..n {
            sess.run(&[], &[&oname], &[])?;
        }
        let dt = t0.elapsed();
        let (pstats, xstats) = sess.step_stats(&[], &[&oname], &[]).unwrap();
        println!(
            "{label:>15}: {layers} layers x {seq_len} steps  {:.1} steps/s  \
             (devices used: {}, cross-device transfers: {}, output {v0:.5})",
            n as f64 / dt.as_secs_f64(),
            pstats.per_device.len(),
            xstats.transfers,
        );
    }
    Ok(())
}
