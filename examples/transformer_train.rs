//! End-to-end driver (E16): train a transformer language model whose
//! train step is the AOT-compiled JAX/Pallas artifact (L2+L1), executed by
//! the rust coordinator through the `XlaCall` op — the full three-layer
//! stack. L3 owns the data pipeline, variables, step loop, checkpoints,
//! summaries; Python never runs.
//!
//!     make artifacts && cargo run --release --example transformer_train -- [steps] [preset]
//! presets: tiny (default, ~1M params), small (~8M), base (~25M), 100m

use rustflow::runtime::artifact_dir;
use rustflow::xla_model::{TransformerConfig, XlaTrainer};

fn main() -> rustflow::Result<()> {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let steps: usize = args.first().and_then(|s| s.parse().ok()).unwrap_or(200);
    let preset = args.get(1).map(|s| s.as_str()).unwrap_or("tiny");
    let cfg = TransformerConfig::preset(preset)?;
    println!(
        "transformer preset {preset}: {} params, seq {}, batch {}, vocab {}",
        cfg.num_params(),
        cfg.seq_len,
        cfg.batch,
        cfg.vocab
    );
    let dir = artifact_dir();
    let mut trainer = XlaTrainer::new(&dir, &cfg, 42)?;
    let t0 = std::time::Instant::now();
    let mut first = None;
    let mut losses = Vec::new();
    for step in 0..steps {
        let loss = trainer.train_step()?;
        first.get_or_insert(loss);
        losses.push(loss);
        if step % 20 == 0 || step + 1 == steps {
            println!("step {step:>4}  loss {loss:.4}");
        }
    }
    let dt = t0.elapsed();
    let toks = (cfg.batch * cfg.seq_len * steps) as f64;
    println!(
        "{steps} steps in {dt:?}  ({:.1} steps/s, {:.0} tokens/s); loss {:.4} -> {:.4}",
        steps as f64 / dt.as_secs_f64(),
        toks / dt.as_secs_f64(),
        first.unwrap(),
        losses.last().unwrap()
    );
    // Loss must trend downward over the run.
    let head: f32 = losses[..losses.len() / 4].iter().sum::<f32>() / (losses.len() / 4) as f32;
    let tail: f32 =
        losses[3 * losses.len() / 4..].iter().sum::<f32>() / (losses.len() - 3 * losses.len() / 4) as f32;
    println!("mean loss first quarter {head:.4} vs last quarter {tail:.4}");
    assert!(tail < head, "loss did not decrease");
    Ok(())
}
